//! Bench: regenerate **Figure 2(a)** — total training time (hours) vs
//! recovery time {10, 20, 30} × working pool {4112, 4128, 4160, 4192},
//! Table-I defaults otherwise. Prints the paper's series plus timing.
//!
//! ```bash
//! cargo bench --bench fig2a            # 5 replications/point
//! AIRESIM_BENCH_REPS=30 cargo bench --bench fig2a
//! ```

mod common;

use airesim::config::Params;
use airesim::report;
use airesim::sweep::{run_sweep, Sweep};
use common::{bench_reps, header, timed};

fn main() {
    let reps = bench_reps(5);
    header(&format!("Figure 2(a): recovery time × working pool ({reps} reps/point)"));

    let base = Params::table1_defaults();
    let sweep = Sweep::two_way(
        "Fig 2(a)",
        "recovery_time",
        &[10.0, 20.0, 30.0],
        "working_pool",
        &[4112.0, 4128.0, 4160.0, 4192.0],
        reps,
        42,
    );
    let (result, secs) = timed(|| run_sweep(&base, &sweep, 0));
    print!("{}", report::figure_series(&result, "makespan_hours"));
    print!("{}", report::csv(&result, "makespan_hours"));

    // Paper-shape verdicts.
    let mean = |i: usize| result.points[i].summary("makespan_hours").unwrap().mean;
    let rec_avg: Vec<f64> =
        (0..3).map(|x| (0..4).map(|y| mean(4 * x + y)).sum::<f64>() / 4.0).collect();
    let monotone = rec_avg[0] < rec_avg[1] && rec_avg[1] < rec_avg[2];
    println!(
        "shape: training time rises with recovery time ({:.0} < {:.0} < {:.0} h): {}",
        rec_avg[0],
        rec_avg[1],
        rec_avg[2],
        if monotone { "OK" } else { "MISMATCH" }
    );
    let runs = sweep.points.len() * reps;
    println!(
        "timing: {runs} runs of a 256-day 4096-server job in {secs:.1}s ({:.0} ms/run)",
        secs * 1000.0 / runs as f64
    );
}
