//! Bench: regenerate the full **Table I** parameter study — a one-way
//! sweep over every row's value range (defaults elsewhere), reporting the
//! mean training time per value and the §IV sensitivity ranking.
//!
//! ```bash
//! cargo bench --bench table1
//! AIRESIM_BENCH_REPS=10 cargo bench --bench table1
//! # machine-readable per-axis timings (see BENCH_PR6.json):
//! AIRESIM_BENCH_JSON=BENCH_PR6.json cargo bench --bench table1
//! ```

mod common;

use airesim::config::Params;
use airesim::report;
use airesim::sweep::{run_sweep, Sweep, SweepResult};
use common::{bench_reps, header, timed, BenchRecorder};

fn main() {
    let mut rec = BenchRecorder::new("table1");
    let reps = bench_reps(3);
    header(&format!("Table I: one-way sweeps over every parameter ({reps} reps/point)"));

    let base = Params::table1_defaults();
    // Every row of Table I with its printed value range.
    let axes: Vec<(&str, Vec<f64>)> = vec![
        ("random_failure_rate",
         vec![0.005 / 1440.0, 0.01 / 1440.0, 0.025 / 1440.0, 0.05 / 1440.0]),
        ("systematic_rate_multiplier", vec![3.0, 5.0, 10.0]),
        ("systematic_fraction", vec![0.1, 0.15, 0.2]),
        ("recovery_time", vec![10.0, 20.0, 30.0]),
        ("warm_standbys", vec![4.0, 8.0, 16.0, 32.0]),
        ("host_selection_time", vec![1.0, 3.0, 5.0, 10.0]),
        ("waiting_time", vec![10.0, 20.0, 30.0]),
        ("auto_repair_prob", vec![0.70, 0.80, 0.90]),
        ("auto_repair_fail_prob", vec![0.2, 0.4, 0.6]),
        ("manual_repair_fail_prob", vec![0.1, 0.2, 0.3]),
        ("auto_repair_time", vec![60.0, 120.0, 180.0]),
        ("manual_repair_time", vec![1440.0, 2.0 * 1440.0, 3.0 * 1440.0]),
        ("working_pool", vec![4112.0, 4128.0, 4160.0, 4192.0]),
        ("spare_pool", vec![200.0, 300.0, 400.0]),
        ("diagnosis_prob", vec![0.6, 0.8, 1.0]),
    ];

    let mut results: Vec<(String, SweepResult)> = Vec::new();
    let mut total_runs = 0usize;
    let ((), secs) = timed(|| {
        for (name, values) in &axes {
            let sweep = Sweep::one_way(name, name, values, reps, 42);
            total_runs += sweep.points.len() * reps;
            let (r, axis_secs) = timed(|| run_sweep(&base, &sweep, 0));
            print!("{}", report::text_table(&r, "makespan_hours"));
            let events: f64 = r
                .points
                .iter()
                .map(|pt| {
                    pt.collector
                        .values("events_delivered")
                        .map(|v| v.iter().sum::<f64>())
                        .unwrap_or(0.0)
                })
                .sum();
            rec.record(
                name,
                base.total_servers() as u64,
                events as u64,
                0,
                axis_secs,
            );
            results.push((name.to_string(), r));
        }
    });

    header("§IV sensitivity ranking");
    print!("{}", report::sensitivity(&results, "makespan_hours"));
    println!(
        "\npaper's finding: only recovery time (and, at zero pool slack, waiting\n\
         time) materially moves training time; everything else is flat at the\n\
         Table I defaults. Check the spread column above against that claim."
    );
    println!(
        "timing: {total_runs} runs in {secs:.1}s ({:.0} ms/run)",
        secs * 1000.0 / total_runs as f64
    );
    rec.flush();
}
