//! Bench: the analytical baseline (§II-C DES-vs-analytical comparison).
//!
//! Measures (1) PJRT artifact batch latency/throughput, (2) the pure-Rust
//! mirror's latency, (3) a DES run for scale, and (4) ranking agreement
//! between the analytical screen and the DES on the Fig 2(a) grid — the
//! property that makes analytical pre-screening of large grids sound.
//!
//! ```bash
//! cargo bench --bench analytic
//! ```

mod common;

use airesim::analytical;
use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::runtime::{AnalyticModel, BATCH};
use airesim::sim::rng::Rng;
use common::{header, median_time, timed};

fn main() {
    header("Analytical baseline: PJRT artifact vs pure-Rust mirror vs DES");

    // Grid of 64 configs (one artifact batch).
    let mut configs = Vec::new();
    for i in 0..BATCH {
        let mut p = Params::table1_defaults();
        p.recovery_time = 10.0 + (i % 8) as f64 * 2.5;
        p.working_pool = 4112 + 16 * (i as u32 / 8 % 8);
        configs.push(p);
    }

    // Pure-Rust mirror.
    let t_rust = median_time(5, || {
        for p in &configs {
            std::hint::black_box(analytical::analyze(p));
        }
    });
    println!(
        "pure-Rust mirror : {:>9.3} ms / 64-config batch ({:.0} configs/s)",
        t_rust * 1e3,
        64.0 / t_rust
    );

    // PJRT artifact (needs both the `pjrt` feature and a built artifact;
    // the default build's stub loader always errors).
    let path = AnalyticModel::default_path();
    if cfg!(feature = "pjrt") && std::path::Path::new(path).exists() {
        let (model, t_load) = timed(|| AnalyticModel::load(path).expect("load artifact"));
        println!("PJRT load+compile: {:>9.1} ms (once per process)", t_load * 1e3);
        let t_pjrt = median_time(5, || {
            std::hint::black_box(model.analyze_many(&configs).expect("exec"));
        });
        println!(
            "PJRT artifact    : {:>9.3} ms / 64-config batch ({:.0} configs/s, platform {})",
            t_pjrt * 1e3,
            64.0 / t_pjrt,
            model.platform()
        );

        // Ranking agreement on the Fig 2(a) grid.
        let mut grid = Vec::new();
        for rec in [10.0, 20.0, 30.0] {
            for wp in [4112u32, 4160, 4192] {
                let mut p = Params::table1_defaults();
                p.recovery_time = rec;
                p.working_pool = wp;
                grid.push(p);
            }
        }
        let ana = model.analyze_many(&grid).expect("exec");
        let des: Vec<f64> = grid
            .iter()
            .map(|p| {
                (0..3)
                    .map(|r| Simulation::with_rng(p, Rng::derived(13, &[r])).run().makespan)
                    .sum::<f64>()
                    / 3.0
            })
            .collect();
        let mut rank_ana: Vec<usize> = (0..grid.len()).collect();
        rank_ana.sort_by(|&a, &b| ana[a].makespan_est.partial_cmp(&ana[b].makespan_est).unwrap());
        let mut rank_des: Vec<usize> = (0..grid.len()).collect();
        rank_des.sort_by(|&a, &b| des[a].partial_cmp(&des[b]).unwrap());
        // Spearman correlation of the two rankings.
        let n = grid.len() as f64;
        let mut pos_ana = vec![0usize; grid.len()];
        let mut pos_des = vec![0usize; grid.len()];
        for (r, &i) in rank_ana.iter().enumerate() {
            pos_ana[i] = r;
        }
        for (r, &i) in rank_des.iter().enumerate() {
            pos_des[i] = r;
        }
        let d2: f64 = (0..grid.len())
            .map(|i| {
                let d = pos_ana[i] as f64 - pos_des[i] as f64;
                d * d
            })
            .sum();
        let rho = 1.0 - 6.0 * d2 / (n * (n * n - 1.0));
        println!("DES-vs-analytic rank correlation (Spearman ρ) on Fig 2(a) grid: {rho:.3}");
    } else {
        println!("(PJRT artifact missing — run `make artifacts` first)");
    }

    // One DES run for scale.
    let p = Params::table1_defaults();
    let (_, t_des) = timed(|| Simulation::new(&p, 42).run());
    println!(
        "one DES run      : {:>9.1} ms (256-day 4096-server job)",
        t_des * 1e3
    );
    println!(
        "screening speedup: analytical ≈ {:.0}× faster than one DES replication",
        t_des / (t_rust / 64.0)
    );
}
