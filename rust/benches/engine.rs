//! Bench: DES core throughput — the §Perf numbers for Layer 3.
//!
//! * event-queue micro: schedule+pop ops/s at several heap depths
//! * end-to-end events/s on the Table-I run
//! * gang fast path vs per-server failure clocks (the headline
//!   optimization recorded in EXPERIMENTS.md §Perf)
//!
//! ```bash
//! cargo bench --bench engine
//! ```

mod common;

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::sim::engine::Engine;
use airesim::sim::rng::Rng;
use common::{header, median_time, timed};

fn main() {
    header("Event-queue micro: schedule+pop throughput");
    for depth in [1_000usize, 10_000, 100_000] {
        let ops = 1_000_000usize;
        let t = median_time(3, || {
            let mut e: Engine<u64> = Engine::with_capacity(depth);
            let mut rng = Rng::new(1);
            // Pre-fill to the target depth.
            for i in 0..depth {
                e.schedule_at(rng.next_f64() * 1e6, i as u64);
            }
            // Steady-state churn: pop one, push one.
            for i in 0..ops {
                let (t, _) = e.pop().unwrap();
                e.schedule_at(t + rng.next_f64() * 1e3, i as u64);
            }
        });
        println!(
            "depth {depth:>7}: {:>6.1} M ops/s",
            ops as f64 / t / 1e6
        );
    }

    header("End-to-end: Table-I default run");
    let p = Params::table1_defaults();
    let (out, secs) = timed(|| Simulation::new(&p, 42).run());
    println!(
        "gang fast path   : {:>8.1} ms, {} events ({:.2} M events/s), {} failures",
        secs * 1e3,
        out.events_delivered,
        out.events_delivered as f64 / secs / 1e6,
        out.failures_total
    );

    let (out2, secs2) = timed(|| {
        Simulation::new(&p, 42).with_per_server_clocks().run()
    });
    println!(
        "per-server clocks: {:>8.1} ms, {} events ({:.2} M events/s), {} failures",
        secs2 * 1e3,
        out2.events_delivered,
        out2.events_delivered as f64 / secs2 / 1e6,
        out2.failures_total
    );
    println!(
        "fast-path speedup: {:.1}× wall-clock, {:.0}× fewer events",
        secs2 / secs,
        out2.events_delivered as f64 / out.events_delivered as f64
    );

    header("Sweep scaling across threads (12-point Fig-2a grid, 2 reps)");
    use airesim::sweep::{run_sweep, Sweep};
    let sweep = Sweep::two_way(
        "scal",
        "recovery_time",
        &[10.0, 20.0, 30.0],
        "working_pool",
        &[4112.0, 4128.0, 4160.0, 4192.0],
        2,
        42,
    );
    let mut t1 = 0.0;
    for threads in [1usize, 2, 4, 8] {
        let (_, t) = timed(|| run_sweep(&p, &sweep, threads));
        if threads == 1 {
            t1 = t;
        }
        println!(
            "threads {threads}: {:>6.2} s  (speedup {:.2}×)",
            t,
            t1 / t
        );
    }
}
