//! Bench: DES core throughput — the §Perf numbers for Layer 3.
//!
//! * event-queue micro: schedule+pop ops/s at several depths, calendar
//!   queue vs binary heap
//! * end-to-end events/s on the Table-I run under both queue kinds
//! * gang fast path vs per-server failure clocks (the original headline
//!   optimization), plus thinned vs per-server clocks on a large Weibull
//!   fleet (this PR's headline: aggregate clocks for non-exponential
//!   families)
//!
//! ```bash
//! cargo bench --bench engine
//! # machine-readable trajectory (see BENCH_PR6.json):
//! AIRESIM_BENCH_JSON=BENCH_PR6.json cargo bench --bench engine
//! # CI smoke scale:
//! AIRESIM_BENCH_REPS=1 AIRESIM_BENCH_FLEET=512 cargo bench --bench engine
//! ```

mod common;

use airesim::config::{DistKind, Params};
use airesim::model::cluster::Simulation;
use airesim::model::PolicySpec;
use airesim::sim::engine::{Engine, QueueKind};
use airesim::sim::rng::Rng;
use common::{bench_reps, header, median_time, timed, BenchRecorder};

fn kind_name(kind: QueueKind) -> &'static str {
    match kind {
        QueueKind::Calendar => "calendar",
        QueueKind::Heap => "heap",
    }
}

/// Weibull fleet size for the thinning section (override:
/// AIRESIM_BENCH_FLEET; CI smoke uses a small value).
fn bench_fleet(default: u32) -> u32 {
    std::env::var("AIRESIM_BENCH_FLEET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let mut rec = BenchRecorder::new("engine");

    header("Event-queue micro: schedule+pop throughput (hold-model churn)");
    for kind in [QueueKind::Calendar, QueueKind::Heap] {
        for depth in [1_000usize, 10_000, 100_000] {
            let ops = 1_000_000usize;
            let t = median_time(3, || {
                let mut e: Engine<u64> = Engine::with_queue(kind, depth);
                let mut rng = Rng::new(1);
                // Pre-fill to the target depth.
                for i in 0..depth {
                    e.schedule_at(rng.next_f64() * 1e6, i as u64);
                }
                // Steady-state churn: pop one, push one.
                for i in 0..ops {
                    let (t, _) = e.pop().unwrap();
                    e.schedule_at(t + rng.next_f64() * 1e3, i as u64);
                }
            });
            println!(
                "{:<8} depth {depth:>7}: {:>6.1} M ops/s",
                kind_name(kind),
                ops as f64 / t / 1e6
            );
            rec.record(
                &format!("micro_{}_{depth}", kind_name(kind)),
                depth as u64,
                ops as u64,
                ops as u64,
                t,
            );
        }
    }

    header("End-to-end: Table-I default run, calendar vs heap");
    let p = Params::table1_defaults();
    for kind in [QueueKind::Calendar, QueueKind::Heap] {
        let (out, secs) =
            timed(|| Simulation::new(&p, 42).with_queue(kind).run());
        println!(
            "{:<8} queue: {:>8.1} ms, {} events ({:.2} M events/s), {} failures",
            kind_name(kind),
            secs * 1e3,
            out.events_delivered,
            out.events_delivered as f64 / secs / 1e6,
            out.failures_total
        );
        rec.record(
            &format!("table1_gang_{}", kind_name(kind)),
            p.total_servers() as u64,
            out.events_delivered,
            out.events_scheduled,
            secs,
        );
    }

    header("Failure-clock models on the Table-I run (exponential)");
    let (out, secs) = timed(|| Simulation::new(&p, 42).run());
    println!(
        "gang fast path   : {:>8.1} ms, {} events ({:.2} M events/s), {} failures",
        secs * 1e3,
        out.events_delivered,
        out.events_delivered as f64 / secs / 1e6,
        out.failures_total
    );
    let (out2, secs2) =
        timed(|| Simulation::new(&p, 42).with_per_server_clocks().run());
    println!(
        "per-server clocks: {:>8.1} ms, {} events ({:.2} M events/s), {} failures",
        secs2 * 1e3,
        out2.events_delivered,
        out2.events_delivered as f64 / secs2 / 1e6,
        out2.failures_total
    );
    println!(
        "fast-path speedup: {:.1}× wall-clock, {:.0}× fewer events",
        secs2 / secs,
        out2.events_delivered as f64 / out.events_delivered as f64
    );
    rec.record(
        "table1_per_server",
        p.total_servers() as u64,
        out2.events_delivered,
        out2.events_scheduled,
        secs2,
    );

    header("Thinned aggregate clocks: Weibull fleet, thinned vs per-server");
    let fleet_n = bench_fleet(10_000);
    let mut w = Params::table1_defaults();
    w.failure_dist = DistKind::Weibull { shape: 1.5 };
    w.num_jobs = 1;
    w.working_pool = fleet_n;
    w.job_size = fleet_n / 32 * 31;
    w.warm_standbys = fleet_n / 64;
    w.spare_pool = (fleet_n / 32).max(8);
    w.job_len = 365.0 * 1440.0; // horizon-bound: fixed simulated length
    w.max_sim_time = 30.0 * 1440.0;
    let mut run = |failure: &'static str| {
        let mut spec = PolicySpec::default();
        spec.set("failure", failure).unwrap();
        let (out, secs) = timed(|| {
            Simulation::from_spec(&w, &spec, Rng::new(42))
                .expect("bench spec builds")
                .run()
        });
        println!(
            "{failure:<11}: {:>8.1} ms, {} scheduled / {} delivered, {} failures",
            secs * 1e3,
            out.events_scheduled,
            out.events_delivered,
            out.failures_total
        );
        rec.record(
            &format!("weibull_{failure}"),
            w.total_servers() as u64,
            out.events_delivered,
            out.events_scheduled,
            secs,
        );
        (out, secs)
    };
    let (thin, thin_secs) = run("thinned");
    let (per, per_secs) = run("per_server");
    println!(
        "thinning win: {:.1}× fewer scheduled events, {:.1}× wall-clock \
         ({} vs {} failures — statistically equivalent, see tests/thinning.rs)",
        per.events_scheduled as f64 / thin.events_scheduled.max(1) as f64,
        per_secs / thin_secs,
        thin.failures_total,
        per.failures_total
    );

    header("Sweep scaling across threads (12-point Fig-2a grid)");
    use airesim::sweep::{run_sweep, Sweep};
    let reps = bench_reps(2);
    let sweep = Sweep::two_way(
        "scal",
        "recovery_time",
        &[10.0, 20.0, 30.0],
        "working_pool",
        &[4112.0, 4128.0, 4160.0, 4192.0],
        reps,
        42,
    );
    let mut t1 = 0.0;
    for threads in [1usize, 2, 4, 8] {
        let (_, t) = timed(|| run_sweep(&p, &sweep, threads));
        if threads == 1 {
            t1 = t;
        }
        println!("threads {threads}: {:>6.2} s  (speedup {:.2}×)", t, t1 / t);
    }

    rec.flush();
}
