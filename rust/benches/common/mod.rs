//! Shared measurement kit for the bench harnesses (criterion is not in
//! the offline vendor set; these benches are `harness = false` binaries
//! that print the paper's tables/series plus wall-clock timings).
//!
//! Machine-readable mode: set `AIRESIM_BENCH_JSON=<path>` (or pass
//! `--json <path>` after `--`) and every bench that carries a
//! [`BenchRecorder`] appends its timings to that file as one JSON array —
//! the committed `BENCH_*.json` perf-trajectory baselines are produced
//! this way (delete the file first to regenerate from scratch).

#![allow(dead_code)] // each bench uses a subset of these helpers

use airesim::report::json::Json;
use std::path::PathBuf;
use std::time::Instant;

/// Replications per sweep point (override: AIRESIM_BENCH_REPS).
pub fn bench_reps(default: usize) -> usize {
    std::env::var("AIRESIM_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median-of-N timing for micro-measurements.
pub fn median_time(n: usize, mut f: impl FnMut()) -> f64 {
    let mut times: Vec<f64> = (0..n)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[n / 2]
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable timing sink: collects one JSON object per measurement
/// and merges them into a single top-level JSON array on [`flush`].
/// Inactive (every call a no-op) unless `AIRESIM_BENCH_JSON` or a
/// `--json <path>` argument names the output file, so plain bench runs
/// keep their text-only behavior.
///
/// [`flush`]: BenchRecorder::flush
pub struct BenchRecorder {
    bench: &'static str,
    path: Option<PathBuf>,
    rows: Vec<Json>,
}

impl BenchRecorder {
    /// `bench` tags every record with the emitting harness (`"engine"`,
    /// `"table1"`, ...) so several benches can share one trajectory file.
    pub fn new(bench: &'static str) -> BenchRecorder {
        let mut path = std::env::var("AIRESIM_BENCH_JSON").ok().map(PathBuf::from);
        // `cargo bench --bench engine -- --json out.json`
        let args: Vec<String> = std::env::args().collect();
        for (i, a) in args.iter().enumerate() {
            if a == "--json" {
                if let Some(p) = args.get(i + 1) {
                    path = Some(PathBuf::from(p));
                }
            }
        }
        BenchRecorder { bench, path, rows: Vec::new() }
    }

    /// Is a JSON sink configured?
    pub fn active(&self) -> bool {
        self.path.is_some()
    }

    /// Record one measurement. `events_per_sec` is derived from
    /// `events_delivered / wall_seconds`; pass 0 events for measurements
    /// where only wall time is meaningful.
    pub fn record(
        &mut self,
        name: &str,
        fleet_size: u64,
        events_delivered: u64,
        events_scheduled: u64,
        wall_seconds: f64,
    ) {
        if self.path.is_none() {
            return;
        }
        let eps = if wall_seconds > 0.0 {
            events_delivered as f64 / wall_seconds
        } else {
            0.0
        };
        self.rows.push(Json::obj([
            ("bench", Json::str(self.bench)),
            ("name", Json::str(name)),
            ("fleet_size", Json::from(fleet_size)),
            ("events_delivered", Json::from(events_delivered)),
            ("events_scheduled", Json::from(events_scheduled)),
            ("wall_seconds", Json::Num(wall_seconds)),
            ("events_per_sec", Json::Num(eps)),
        ]));
    }

    /// Merge this run's records into the output file, preserving any
    /// records already there (so `engine` then `table1` produce one valid
    /// array). The file stays a single top-level JSON array with one
    /// compact object per line — `python3 -m json.tool` validates it,
    /// `jq` slices it.
    pub fn flush(&mut self) {
        let Some(path) = self.path.clone() else { return };
        if self.rows.is_empty() {
            return;
        }
        // Pull existing entries out of a previous `[ ... ]` document.
        let existing = std::fs::read_to_string(&path).ok().and_then(|s| {
            let t = s.trim();
            let inner = t.strip_prefix('[')?.strip_suffix(']')?.trim();
            (!inner.is_empty()).then(|| inner.to_string())
        });
        let mut body = String::new();
        if let Some(inner) = existing {
            body.push_str(&inner);
            body.push_str(",\n");
        }
        let fresh: Vec<String> = self.rows.iter().map(Json::render).collect();
        body.push_str(&fresh.join(",\n"));
        let doc = format!("[\n{body}\n]\n");
        match std::fs::write(&path, doc) {
            Ok(()) => eprintln!(
                "bench[{}]: appended {} records to {}",
                self.bench,
                self.rows.len(),
                path.display()
            ),
            Err(e) => eprintln!(
                "bench[{}]: FAILED to write {}: {e}",
                self.bench,
                path.display()
            ),
        }
        self.rows.clear();
    }
}
