//! Shared measurement kit for the bench harnesses (criterion is not in
//! the offline vendor set; these benches are `harness = false` binaries
//! that print the paper's tables/series plus wall-clock timings).

#![allow(dead_code)] // each bench uses a subset of these helpers

use std::time::Instant;

/// Replications per sweep point (override: AIRESIM_BENCH_REPS).
pub fn bench_reps(default: usize) -> usize {
    std::env::var("AIRESIM_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median-of-N timing for micro-measurements.
pub fn median_time(n: usize, mut f: impl FnMut()) -> f64 {
    let mut times: Vec<f64> = (0..n)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[n / 2]
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}
