//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Retirement policy (§II-B): threshold sweep under systematic pressure
//!    with unreliable repairs — when does retiring beat re-repairing?
//! 2. Finite repair-shop capacity (extension knob): queueing effects as
//!    technician count shrinks.
//! 3. Host-selection policy: first-fit (LIFO) vs random vs locality.
//! 4. Repair queue discipline: FIFO vs job-first priority under a
//!    capacity-constrained shop.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

mod common;

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::model::PolicySpec;
use airesim::sim::rng::Rng;
use airesim::stats::Summary;
use common::{bench_reps, header};

/// High-systematic-pressure base: repairs rarely fix the defect, so bad
/// servers keep cycling — the regime where retirement should matter.
fn pressure_params() -> Params {
    let mut p = Params::table1_defaults();
    p.systematic_fraction = 0.25;
    p.systematic_failure_rate = 20.0 * p.random_failure_rate;
    p.auto_repair_fail_prob = 0.9;
    p.manual_repair_fail_prob = 0.8;
    p.job_len = 64.0 * 1440.0; // 64 days: keeps the bench quick
    p
}

fn run_mean(p: &Params, reps: usize, f: impl Fn(&airesim::model::RunOutputs) -> f64) -> Summary {
    let vals: Vec<f64> = (0..reps)
        .map(|r| f(&Simulation::with_rng(p, Rng::derived(3, &[r as u64])).run()))
        .collect();
    Summary::from_values(&vals).unwrap()
}

fn main() {
    let reps = bench_reps(5);

    header(&format!("Ablation 1: retirement threshold ({reps} reps)"));
    println!(
        "{:>10} {:>14} {:>12} {:>12} {:>12}",
        "threshold", "makespan(h)", "failures", "retired", "preempts"
    );
    for threshold in [0u32, 2, 3, 5, 8] {
        let mut p = pressure_params();
        p.retirement_threshold = threshold;
        p.retirement_window = 14.0 * 1440.0;
        let mk = run_mean(&p, reps, |o| o.makespan / 60.0);
        let fl = run_mean(&p, reps, |o| o.failures_total as f64);
        let rt = run_mean(&p, reps, |o| o.retirements as f64);
        let pr = run_mean(&p, reps, |o| o.preemptions as f64);
        println!(
            "{:>10} {:>14.1} {:>12.0} {:>12.0} {:>12.0}",
            threshold, mk.mean, fl.mean, rt.mean, pr.mean
        );
    }
    println!(
        "observed shape: aggressive thresholds (2-3) retire hundreds of servers,\n\
         exhaust the spare pool, and stall the job to the horizon — the paper's\n\
         SSII-B caveat (\"reducing the cluster's capacity\") made concrete. A high\n\
         threshold (5) trims repeat offenders without the capacity collapse;\n\
         retirement is only safe when the retirement budget fits the spare pool."
    );

    header(&format!("Ablation 2: manual repair-shop capacity ({reps} reps)"));
    println!(
        "{:>10} {:>14} {:>12} {:>12}",
        "capacity", "makespan(h)", "failures", "stall(min)"
    );
    for cap in [0u32, 64, 16, 4, 1] {
        let mut p = pressure_params();
        p.manual_repair_capacity = cap;
        let mk = run_mean(&p, reps, |o| o.makespan / 60.0);
        let fl = run_mean(&p, reps, |o| o.failures_total as f64);
        let st = run_mean(&p, reps, |o| o.stall_time);
        println!(
            "{:>10} {:>14.1} {:>12.0} {:>12.1}",
            if cap == 0 { "unlimited".to_string() } else { cap.to_string() },
            mk.mean,
            fl.mean,
            st.mean
        );
    }
    println!(
        "expected shape: below some technician count, repair queueing starves the\n\
         working pool and stalls appear."
    );

    header(&format!("Ablation 3: host-selection policy ({reps} reps)"));
    for name in ["first_fit", "random", "locality"] {
        let p = pressure_params();
        let mut spec = PolicySpec::default();
        spec.set("selection", name).unwrap();
        let vals: Vec<f64> = (0..reps)
            .map(|r| {
                Simulation::from_spec(&p, &spec, Rng::derived(9, &[r as u64]))
                    .expect("spec builds")
                    .run()
                    .makespan
                    / 60.0
            })
            .collect();
        let s = Summary::from_values(&vals).unwrap();
        println!("{name:<18}: {:>10.1} ± {:.1} h", s.mean, s.ci95_halfwidth());
    }
    println!(
        "expected shape: with i.i.d. failure identities the policies tie; random\n\
         placement only matters once regeneration correlates badness with history."
    );

    header(&format!("Ablation 4: repair queue discipline ({reps} reps)"));
    for name in ["fifo", "lifo", "job_first"] {
        let mut p = pressure_params();
        p.manual_repair_capacity = 4; // queueing regime: discipline matters
        let mut spec = PolicySpec::default();
        spec.set("repair", name).unwrap();
        let vals: Vec<f64> = (0..reps)
            .map(|r| {
                Simulation::from_spec(&p, &spec, Rng::derived(21, &[r as u64]))
                    .expect("spec builds")
                    .run()
                    .makespan
                    / 60.0
            })
            .collect();
        let s = Summary::from_values(&vals).unwrap();
        println!("{name:<18}: {:>10.1} ± {:.1} h", s.mean, s.ci95_halfwidth());
    }
    println!(
        "expected shape: job-first returns gang members to their jobs sooner,\n\
         trimming stalls when the shop saturates."
    );
}
