//! Multi-job extension (assumption 6 lifted): N identical jobs contending
//! for the same working/spare pools and repair shop.

use airesim::config::{validate, Params};
use airesim::model::cluster::Simulation;
use airesim::model::job::JobPhase;
use airesim::sim::rng::Rng;

/// Pools sized for exactly `k` concurrent small jobs.
fn params_for_jobs(k: u32, pool_per_job: u32) -> Params {
    let mut p = Params::small_test();
    p.num_jobs = k;
    p.job_size = 32;
    p.warm_standbys = 4;
    p.working_pool = pool_per_job * k.max(1);
    p.spare_pool = 8;
    p.job_len = 1440.0;
    p.max_sim_time = 1e7;
    p
}

#[test]
fn two_jobs_with_ample_pools_both_complete() {
    let p = params_for_jobs(2, 40); // 80 working servers for 2×(32+4)
    let out = Simulation::new(&p, 1).run();
    assert!(out.completed);
    assert_eq!(out.per_job_makespans.len(), 2);
    for (j, &m) in out.per_job_makespans.iter().enumerate() {
        assert!(m >= p.job_len, "job {j} finished impossibly fast: {m}");
    }
    assert!((out.makespan
        - out.per_job_makespans.iter().cloned().fold(0.0f64, f64::max))
    .abs()
        < 1e-9);
}

#[test]
fn single_job_behaviour_unchanged() {
    // num_jobs=1 must reproduce the pre-extension outputs exactly.
    let mut p = Params::small_test();
    p.num_jobs = 1;
    let a = Simulation::new(&p, 7).run();
    let b = Simulation::new(&Params::small_test(), 7).run();
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.failures_total, b.failures_total);
    assert_eq!(a.per_job_makespans.len(), 1);
}

#[test]
fn insufficient_pools_serialize_jobs() {
    // Pools fit one job at a time: job 1 must queue behind job 0 and both
    // finish — sequentially.
    let mut p = params_for_jobs(2, 20); // 40 working total, one job needs 32
    p.spare_pool = 0;
    p.random_failure_rate = 0.0; // failure-free: exact timing
    p.systematic_failure_rate = 0.0;
    let out = Simulation::new(&p, 2).run();
    assert!(out.completed);
    let (m0, m1) = (out.per_job_makespans[0], out.per_job_makespans[1]);
    // Job 0 runs immediately; job 1 starts only after job 0 releases.
    assert!((m0 - (p.host_selection_time + p.job_len)).abs() < 1e-6);
    assert!(
        m1 >= m0 + p.job_len,
        "job 1 ({m1}) should run after job 0 ({m0})"
    );
    // Stall accounting covers job 1's wait.
    assert!(out.stall_time >= p.job_len - 1e-6);
}

#[test]
fn contention_conservation_holds() {
    let mut p = params_for_jobs(3, 24); // deliberately tight: 72 for 3×36
    p.spare_pool = 12;
    p.random_failure_rate = 1.0 / 1440.0;
    p.systematic_failure_rate = 5.0 / 1440.0;
    let mut sim = Simulation::new(&p, 5);
    sim.prime();
    let mut steps = 0;
    while sim.step() {
        steps += 1;
        if steps % 8 == 0 {
            assert!(sim.conservation_ok(), "violated at event {steps}");
        }
        if steps > 300_000 {
            break;
        }
    }
    assert!(sim.conservation_ok());
}

#[test]
fn jobs_do_not_share_servers() {
    let p = params_for_jobs(2, 40);
    let mut sim = Simulation::new(&p, 3);
    sim.prime();
    for _ in 0..5000 {
        if !sim.step() {
            break;
        }
        let a: Vec<u32> = sim.jobs()[0]
            .active
            .iter()
            .chain(&sim.jobs()[0].standbys)
            .copied()
            .collect();
        for id in sim.jobs()[1].active.iter().chain(&sim.jobs()[1].standbys) {
            assert!(!a.contains(id), "server {id} in both jobs");
        }
        if sim.jobs().iter().all(|j| j.phase == JobPhase::Done) {
            break;
        }
    }
}

#[test]
fn more_jobs_mean_more_failures() {
    let mean_failures = |k: u32| -> f64 {
        let p = params_for_jobs(k, 40);
        (0..8)
            .map(|r| {
                Simulation::with_rng(&p, Rng::derived(11, &[k as u64, r]))
                    .run()
                    .failures_total as f64
            })
            .sum::<f64>()
            / 8.0
    };
    let f1 = mean_failures(1);
    let f3 = mean_failures(3);
    assert!(
        f3 > 2.0 * f1,
        "3 jobs should see ~3x the failures: {f3} vs {f1}"
    );
}

#[test]
fn num_jobs_is_sweepable_and_validated() {
    let mut p = Params::table1_defaults();
    assert!(p.set_by_name("num_jobs", 2.0));
    assert_eq!(p.get_by_name("num_jobs"), Some(2.0));
    validate::validate(&p).unwrap();
}
