//! Workload subsystem integration: open-loop arrivals, the admission
//! queue and its metrics, NDJSON trace replay, and the `shortest_first`
//! repair discipline.

use std::collections::{BTreeMap, BTreeSet};

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::model::policy::PolicySpec;
use airesim::model::workload::{parse_replay, ArrivalProcess, WorkloadSpec};
use airesim::sim::rng::Rng;
use airesim::trace::{Trace, TraceKind};

fn poisson(rate: f64) -> Option<WorkloadSpec> {
    Some(WorkloadSpec { arrival: ArrivalProcess::Poisson { rate }, classes: vec![] })
}

/// Pools sized for exactly one small job at a time: arrivals beyond the
/// first must wait in the admission queue.
fn tight_params(num_jobs: u32, rate: f64) -> Params {
    let mut p = Params::small_test();
    p.num_jobs = num_jobs;
    p.job_size = 32;
    p.warm_standbys = 4;
    p.working_pool = 40; // fits one 32+4 job
    p.spare_pool = 0;
    p.job_len = 480.0;
    p.random_failure_rate = 0.0; // failure-free: exact admission timing
    p.systematic_failure_rate = 0.0;
    p.max_sim_time = 1e6;
    p.workload = poisson(rate);
    p
}

/// Recompute the queue accounting independently from the trace: per-job
/// arrival/admission times, the event-walk depth integral, and the peak
/// depth. Still-queued jobs are censored at `horizon` exactly like
/// `SimCtx::finalize`.
struct QueueFromTrace {
    arrived: BTreeMap<u32, f64>,
    admitted_wait: BTreeMap<u32, f64>,
    depth_integral: f64,
    depth_max: u64,
}

fn queue_from_trace(t: &Trace, horizon: f64) -> QueueFromTrace {
    let mut q = QueueFromTrace {
        arrived: BTreeMap::new(),
        admitted_wait: BTreeMap::new(),
        depth_integral: 0.0,
        depth_max: 0,
    };
    let (mut depth, mut prev) = (0u64, 0.0f64);
    for r in &t.records {
        let delta: i64 = match r.kind {
            TraceKind::JobArrival { job, .. } => {
                q.arrived.insert(job, r.at);
                1
            }
            TraceKind::JobAdmitted { job, waited } => {
                q.admitted_wait.insert(job, waited);
                -1
            }
            _ => continue,
        };
        q.depth_integral += depth as f64 * (r.at - prev);
        depth = (depth as i64 + delta) as u64;
        q.depth_max = q.depth_max.max(depth);
        prev = r.at;
    }
    q.depth_integral += depth as f64 * (horizon - prev);
    q
}

#[test]
fn no_workload_reports_no_queue_activity() {
    let p = Params::small_test(); // workload: None
    let (out, trace) = Simulation::new(&p, 42).with_trace().run_traced();
    assert_eq!(out.jobs_arrived, 0);
    assert_eq!(out.jobs_admitted, 0);
    assert_eq!(out.queue_wait_total, 0.0);
    assert_eq!(out.queue_depth_max, 0);
    assert_eq!(out.queue_wait_p50, 0.0);
    assert_eq!(out.queue_wait_p99, 0.0);
    assert_eq!(
        trace.count(|k| matches!(
            k,
            TraceKind::JobArrival { .. } | TraceKind::JobAdmitted { .. }
        )),
        0,
        "legacy closed-loop runs must emit no workload events"
    );
}

#[test]
fn open_loop_arrivals_deliver_every_job() {
    let mut p = tight_params(4, 0.01);
    p.working_pool = 160; // ample: all four jobs fit concurrently
    let (out, trace) = Simulation::new(&p, 7).with_trace().run_traced();
    assert!(out.completed, "ample pools + no failures must finish");
    assert_eq!(out.jobs_arrived, 4);
    assert_eq!(out.jobs_admitted, 4);
    assert_eq!(trace.count(|k| matches!(k, TraceKind::JobArrival { .. })), 4);
    // Ample pools: every arrival is admitted on the spot.
    let q = queue_from_trace(&trace, p.max_sim_time);
    assert!(q.admitted_wait.values().all(|&w| w == 0.0), "{:?}", q.admitted_wait);
    assert_eq!(out.queue_wait_total, 0.0);
    // Arrival events come in time order with drawn (positive) gaps.
    let ats: Vec<f64> = trace
        .records
        .iter()
        .filter(|r| matches!(r.kind, TraceKind::JobArrival { .. }))
        .map(|r| r.at)
        .collect();
    assert!(ats.windows(2).all(|w| w[0] <= w[1]), "{ats:?}");
    assert!(ats[0] > 0.0, "Poisson arrivals draw the first gap too");
}

#[test]
fn empirical_gaps_schedule_exact_arrival_times() {
    let mut p = tight_params(4, 0.0);
    p.workload = Some(WorkloadSpec {
        arrival: ArrivalProcess::Empirical {
            file: "gaps.txt".into(),
            gaps: vec![5.0, 10.0],
        },
        classes: vec![],
    });
    let (_, trace) = Simulation::new(&p, 1).with_trace().run_traced();
    let ats: Vec<f64> = trace
        .records
        .iter()
        .filter(|r| matches!(r.kind, TraceKind::JobArrival { .. }))
        .map(|r| r.at)
        .collect();
    assert_eq!(ats, vec![5.0, 15.0, 20.0, 30.0]);
}

#[test]
fn queue_wait_total_is_the_depth_integral() {
    // Jobs arrive faster than the single-job pool drains them, so a real
    // backlog builds. The metric must equal the time-integral of the
    // queue depth, recomputed here two independent ways from the trace:
    // the event-walk integral and the per-job wait sum (Little's law —
    // L·T = Σ waits = λT·W̄ — ties the two together).
    for seed in [1, 2, 3, 11] {
        let p = tight_params(6, 1.0 / 240.0); // ~2 arrivals per 480-min service
        let (out, trace) = Simulation::new(&p, seed).with_trace().run_traced();
        let q = queue_from_trace(&trace, p.max_sim_time);
        assert_eq!(out.jobs_arrived, q.arrived.len() as u64, "seed {seed}");
        assert_eq!(out.jobs_admitted, q.admitted_wait.len() as u64, "seed {seed}");

        // Per-job wait sum, censoring still-queued jobs at the horizon.
        let mut wait_sum: f64 = q.admitted_wait.values().sum();
        for (job, &at) in &q.arrived {
            if !q.admitted_wait.contains_key(job) {
                wait_sum += p.max_sim_time - at;
            }
        }
        assert!(
            (out.queue_wait_total - wait_sum).abs() < 1e-6,
            "seed {seed}: metric {} vs per-job sum {wait_sum}",
            out.queue_wait_total
        );
        assert!(
            (out.queue_wait_total - q.depth_integral).abs() < 1e-6,
            "seed {seed}: metric {} vs depth integral {}",
            out.queue_wait_total,
            q.depth_integral
        );
        assert_eq!(out.queue_depth_max, q.depth_max, "seed {seed}");

        // Tight pools serialize jobs: someone must actually have waited.
        assert!(out.queue_wait_total > 0.0, "seed {seed}: no backlog formed");
        assert!(out.queue_wait_p50 <= out.queue_wait_p99, "seed {seed}");
    }
}

#[test]
fn arrivals_conserve_into_admissions_and_backlog() {
    // jobs_arrived = jobs_admitted + still-queued-at-horizon, with the
    // backlog read independently off the trace.
    let mut p = tight_params(8, 1.0 / 60.0); // heavy overload
    p.max_sim_time = 1200.0; // cut the horizon while the queue is deep
    let (out, trace) = Simulation::new(&p, 5).with_trace().run_traced();
    let q = queue_from_trace(&trace, p.max_sim_time);
    let still_queued = q.arrived.len() - q.admitted_wait.len();
    assert_eq!(
        out.jobs_arrived,
        out.jobs_admitted + still_queued as u64,
        "arrived {} admitted {} queued {still_queued}",
        out.jobs_arrived,
        out.jobs_admitted
    );
    assert!(still_queued > 0, "overload config should leave a backlog");
    assert!(!out.completed);
}

#[test]
fn replay_round_trip_reproduces_the_timeline() {
    // Record a stochastic run, lift its NDJSON trace, replay it with the
    // clocks silenced: the replayed arrival + failure timeline must be
    // the recorded one, event for event.
    let mut p = Params::small_test();
    p.num_jobs = 3;
    p.job_size = 16;
    p.warm_standbys = 2;
    p.working_pool = 60;
    p.spare_pool = 8;
    p.job_len = 1440.0;
    p.max_sim_time = 1e6;
    // Deterministic mechanics outside the clocks: perfect diagnosis, and
    // repairs so slow no repaired server re-enters within the horizon.
    p.diagnosis_prob = 1.0;
    p.diagnosis_uncertainty = 0.0;
    p.auto_repair_time = 1e9;
    p.manual_repair_time = 1e9;
    p.random_failure_rate = 1.0 / 10_000.0;
    p.systematic_failure_rate = 1.0 / 10_000.0;
    p.workload = poisson(1.0 / 300.0);

    let (rec_out, rec_trace) = Simulation::new(&p, 1234).with_trace().run_traced();
    assert!(rec_out.failures_total > 0, "recording saw no failures — vacuous test");
    let ndjson = rec_trace.to_ndjson();

    let (arrivals, failures) = parse_replay(&ndjson).unwrap();
    assert_eq!(arrivals.len(), rec_out.jobs_arrived as usize);
    assert_eq!(failures.len(), rec_out.failures_total as usize);

    let mut rp = p.clone();
    rp.random_failure_rate = 0.0; // silence the stochastic clocks
    rp.systematic_failure_rate = 0.0;
    rp.num_jobs = arrivals.len() as u32; // what config loading auto-syncs
    rp.workload = Some(WorkloadSpec {
        arrival: ArrivalProcess::Replay {
            file: "recorded.ndjson".into(),
            arrivals,
            failures,
        },
        classes: vec![],
    });
    let (rep_out, rep_trace) = Simulation::new(&rp, 999).with_trace().run_traced();

    let timeline = |t: &Trace| -> Vec<(f64, TraceKind)> {
        t.records
            .iter()
            .filter(|r| {
                matches!(
                    r.kind,
                    TraceKind::JobArrival { .. } | TraceKind::Failure { .. }
                )
            })
            .map(|r| (r.at, r.kind.clone()))
            .collect()
    };
    assert_eq!(timeline(&rec_trace), timeline(&rep_trace));
    assert_eq!(rep_out.failures_total, rec_out.failures_total);
    assert_eq!(rep_out.jobs_arrived, rec_out.jobs_arrived);
    // Identical failures against identical arrivals: same makespan too.
    assert!(
        (rep_out.makespan - rec_out.makespan).abs() < 1e-6,
        "record {} vs replay {}",
        rec_out.makespan,
        rep_out.makespan
    );
}

#[test]
fn workload_runs_are_deterministic_per_seed() {
    let p = tight_params(6, 1.0 / 240.0);
    let a = Simulation::new(&p, 77).run();
    let b = Simulation::new(&p, 77).run();
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.queue_wait_total, b.queue_wait_total);
    assert_eq!(a.jobs_admitted, b.jobs_admitted);
    let c = Simulation::new(&p, 78).run();
    assert_ne!(
        (a.makespan, a.queue_wait_total),
        (c.makespan, c.queue_wait_total),
        "two seeds gave identical workloads (astronomically unlikely)"
    );
}

#[test]
fn shortest_first_runs_to_completion_and_is_deterministic() {
    // A capacity-1 shop under sustained failures keeps a real repair
    // queue, so the SPT discipline actually reorders work.
    let mut p = Params::small_test();
    p.auto_repair_capacity = 1;
    p.manual_repair_capacity = 1;
    p.random_failure_rate = 1.0 / 400.0;
    p.systematic_failure_rate = 1.0 / 400.0;
    let mut spec = PolicySpec::default();
    spec.set("repair", "shortest_first").unwrap();
    let run = |seed: u64| {
        Simulation::from_spec(&p, &spec, Rng::new(seed)).unwrap().run()
    };
    let a = run(3);
    let b = run(3);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.failures_total, b.failures_total);
    assert!(
        a.repairs_auto + a.repairs_manual > 0,
        "no repairs completed — the discipline was never exercised"
    );
}
