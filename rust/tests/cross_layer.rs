//! Cross-layer validation: the pure-Rust analytical mirror vs the DES, and
//! (when the artifact exists) the PJRT-compiled JAX/Pallas model vs the
//! pure-Rust mirror.

use airesim::analytical;
use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::runtime::AnalyticModel;
use airesim::sim::rng::Rng;

/// DES mean makespan over a few replications.
fn des_mean_makespan(p: &Params, reps: u64) -> f64 {
    (0..reps)
        .map(|r| Simulation::with_rng(p, Rng::derived(5, &[r])).run().makespan)
        .sum::<f64>()
        / reps as f64
}

fn mid_params() -> Params {
    // A mid-sized configuration the analytical model should track well:
    // plenty of slack (no stalls), exponential clocks.
    let mut p = Params::small_test();
    p.job_size = 128;
    p.warm_standbys = 8;
    p.working_pool = 160;
    p.spare_pool = 32;
    p.job_len = 10.0 * 1440.0;
    p.random_failure_rate = 0.2 / 1440.0;
    p.systematic_failure_rate = 1.0 / 1440.0;
    p.max_sim_time = 1e9;
    p
}

#[test]
fn analytic_tracks_des_makespan() {
    let p = mid_params();
    let des = des_mean_makespan(&p, 12);
    let ana = analytical::analyze(&p).makespan_est;
    let rel = (des - ana).abs() / des;
    assert!(
        rel < 0.15,
        "analytic {ana:.0} vs DES {des:.0} diverge by {:.1}%",
        rel * 100.0
    );
}

#[test]
fn analytic_tracks_des_failure_count() {
    let p = mid_params();
    let reps = 12u64;
    let des: f64 = (0..reps)
        .map(|r| {
            Simulation::with_rng(&p, Rng::derived(6, &[r])).run().failures_total as f64
        })
        .sum::<f64>()
        / reps as f64;
    let ana = analytical::analyze(&p).exp_failures;
    let rel = (des - ana).abs() / des.max(1.0);
    assert!(
        rel < 0.2,
        "analytic {ana:.1} vs DES {des:.1} failures diverge by {:.1}%",
        rel * 100.0
    );
}

#[test]
fn analytic_and_des_rank_recovery_times_identically() {
    // The decision a capacity planner makes — which knob value is better —
    // must agree between the fast analytical screen and the DES.
    let mut makespans_ana = Vec::new();
    let mut makespans_des = Vec::new();
    for rec in [5.0, 30.0, 120.0] {
        let mut p = mid_params();
        p.recovery_time = rec;
        makespans_ana.push(analytical::analyze(&p).makespan_est);
        makespans_des.push(des_mean_makespan(&p, 8));
    }
    assert!(makespans_ana[0] < makespans_ana[1] && makespans_ana[1] < makespans_ana[2]);
    assert!(makespans_des[0] < makespans_des[1] && makespans_des[1] < makespans_des[2]);
}

#[test]
fn pjrt_artifact_matches_rust_mirror() {
    // Gated twice: needs the `pjrt` cargo feature (the default build
    // ships a stub whose load always errors) and `make artifacts` to
    // have produced the HLO text.
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    let path = AnalyticModel::default_path();
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} not built (run `make artifacts`)");
        return;
    }
    let model = AnalyticModel::load(path).expect("artifact should load");

    // A batch of varied configurations.
    let mut configs = Vec::new();
    for rec in [10.0, 20.0, 30.0] {
        for wp in [4112u32, 4128, 4160, 4192] {
            let mut p = Params::table1_defaults();
            p.recovery_time = rec;
            p.working_pool = wp;
            configs.push(p);
        }
    }
    let pjrt = model.analyze_many(&configs).expect("batch execution");
    for (p, out) in configs.iter().zip(&pjrt) {
        let rust = analytical::analyze(p);
        let rel = (out.makespan_est - rust.makespan_est).abs()
            / rust.makespan_est.max(1.0);
        assert!(
            rel < 1e-2,
            "pjrt {} vs rust {} (rel {rel:.2e}) at rec={} wp={}",
            out.makespan_est,
            rust.makespan_est,
            p.recovery_time,
            p.working_pool
        );
        // Availability columns agree tightly too (pure f32 vs f64 noise).
        assert!((out.avail_avg - rust.avail_avg).abs() < 1e-3);
    }
}
