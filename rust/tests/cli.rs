//! CLI end-to-end tests: drive the `airesim` binary the way a user would.
//! (`CARGO_BIN_EXE_airesim` is provided by cargo for integration tests.)

use airesim::report::json::Json;
use airesim::testkit::parse_json;
use std::process::Command;

fn airesim(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_airesim"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

fn obj_get<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

/// Small, fast override set reused across tests.
const SMALL: &str = "job_size=32,working_pool=40,spare_pool=8,warm_standbys=4,job_len=1440,random_failure_rate=0.5/1440,systematic_failure_rate=2.5/1440";

#[test]
fn help_lists_subcommands() {
    let (out, _, ok) = airesim(&["help"]);
    assert!(ok);
    for cmd in ["run", "sweep", "analytic", "whatif", "list-params"] {
        assert!(out.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn run_small_config() {
    let (out, err, ok) = airesim(&["run", "--seed", "7", "--set", SMALL]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("makespan"));
    assert!(out.contains("completed"));
    assert!(out.contains("true"));
}

#[test]
fn run_is_deterministic_across_invocations() {
    let (a, _, _) = airesim(&["run", "--seed", "11", "--set", SMALL]);
    let (b, _, _) = airesim(&["run", "--seed", "11", "--set", SMALL]);
    assert_eq!(a, b);
    let (c, _, _) = airesim(&["run", "--seed", "12", "--set", SMALL]);
    assert_ne!(a, c);
}

#[test]
fn trace_flag_prints_events() {
    let (out, _, ok) = airesim(&["run", "--seed", "7", "--trace", "--set", SMALL]);
    assert!(ok);
    assert!(out.contains("JobStarted"));
    assert!(out.contains("JobCompleted"));
}

#[test]
fn sweep_csv_output() {
    let (out, err, ok) = airesim(&[
        "sweep",
        "--param",
        "recovery_time",
        "--values",
        "10,30",
        "--reps",
        "2",
        "--csv",
        "--set",
        SMALL,
    ]);
    assert!(ok, "stderr: {err}");
    let lines: Vec<&str> = out.trim().lines().collect();
    assert_eq!(lines.len(), 3, "header + 2 rows: {out}");
    assert!(lines[0].starts_with("recovery_time,metric,n,mean"));
}

#[test]
fn sweep_from_config_file() {
    let (out, err, ok) = airesim(&[
        "sweep",
        "--config",
        "configs/fig2a.yaml",
        "--reps",
        "1",
        "--set",
        SMALL,
    ]);
    // Config replications (30) override --reps; that's documented — just
    // assert the grid shape appears.
    assert!(ok, "stderr: {err}");
    assert!(out.contains("recovery_time=10"));
    assert!(out.contains("working_pool=4192"));
}

#[test]
fn whatif_compares_factor() {
    let (out, err, ok) = airesim(&[
        "whatif",
        "--param",
        "recovery_time",
        "--factor",
        "2",
        "--reps",
        "2",
        "--set",
        SMALL,
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("changes mean training time by"));
}

#[test]
fn list_params_covers_table1() {
    let (out, _, ok) = airesim(&["list-params"]);
    assert!(ok);
    for p in ["recovery_time", "working_pool", "warm_standbys", "diagnosis_prob"] {
        assert!(out.contains(p));
    }
}

#[test]
fn analytic_rust_only() {
    let (out, err, ok) = airesim(&["analytic", "--rust-only"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("makespan_est"));
    assert!(out.contains("avail_avg"));
}

#[test]
fn list_policies_covers_every_axis() {
    let (out, _, ok) = airesim(&["list-policies"]);
    assert!(ok);
    for name in [
        "selection",
        "repair",
        "checkpoint",
        "failure",
        "locality",
        "job_first",
        "anti_affinity",
        "power_of_two_choices",
        "correlated",
        "young_daly",
        "adaptive",
        "tiered",
        "sla_aged",
        "history_scored",
    ] {
        assert!(out.contains(name), "list-policies missing {name}");
    }
}

#[test]
fn list_params_covers_selection_history_window() {
    let (out, _, ok) = airesim(&["list-params"]);
    assert!(ok);
    assert!(out.contains("selection_history_window"), "{out}");
}

#[test]
fn scenario_optimize_tune_best_out_round_trips() {
    // The full CLI loop: tune via a temp scenario, write the winner with
    // --best-out, then run the emitted file as a single scenario.
    let dir = std::env::temp_dir();
    let spec = dir.join("airesim_cli_tune.yaml");
    let best = dir.join("airesim_cli_best.yaml");
    std::fs::write(
        &spec,
        "scenario: optimize\nseed: 11\nreplications: 2\n\
         params:\n  job_size: 16\n  working_pool: 24\n  spare_pool: 4\n  warm_standbys: 2\n  job_len: 720\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n  checkpoint_interval: 720\n  checkpoint_cost: 5\n\
         policies:\n  checkpoint: periodic\n\
         optimize:\n  mode: tune\n  knobs:\n    - param: checkpoint_interval\n      values: [30, 720]\n",
    )
    .unwrap();
    let (out, err, ok) = airesim(&[
        "scenario",
        "--config",
        spec.to_str().unwrap(),
        "--best-out",
        best.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("winner:"), "{out}");
    let (out, err, ok) = airesim(&["scenario", "--config", best.to_str().unwrap()]);
    assert!(ok, "best-out file must run: {err}");
    assert!(out.contains("[single]"), "{out}");
    let _ = std::fs::remove_file(&spec);
    let _ = std::fs::remove_file(&best);
}

#[test]
fn best_out_rejects_non_optimize_scenarios() {
    let dir = std::env::temp_dir();
    let spec = dir.join("airesim_cli_single_for_bestout.yaml");
    std::fs::write(
        &spec,
        "scenario: single\nparams:\n  job_size: 16\n  working_pool: 24\n  spare_pool: 4\n  warm_standbys: 2\n  job_len: 720\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n",
    )
    .unwrap();
    let (_, err, ok) =
        airesim(&["scenario", "--config", spec.to_str().unwrap(), "--best-out", "-"]);
    assert!(!ok);
    assert!(err.contains("--best-out"), "{err}");
    let _ = std::fs::remove_file(&spec);
}

#[test]
fn scenario_study_from_file_renders_comparison() {
    // Scale the shipped study down (fewer reps) via a temp copy.
    let cfg = std::env::temp_dir().join("airesim_study_scenario.yaml");
    let text = std::fs::read_to_string("configs/scenario_study.yaml")
        .unwrap()
        .replace("replications: 8", "replications: 2");
    std::fs::write(&cfg, text).unwrap();
    let (out, err, ok) = airesim(&["scenario", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("[multi]"), "{out}");
    assert!(out.contains("baseline locality_periodic"), "{out}");
    assert!(out.contains("anti_affinity_young_daly"), "{out}");

    // JSON mode: one parseable document carrying the comparison table.
    let (out, err, ok) = airesim(&[
        "scenario",
        "--config",
        cfg.to_str().unwrap(),
        "--format",
        "json",
    ]);
    let _ = std::fs::remove_file(&cfg);
    assert!(ok, "stderr: {err}");
    let doc = parse_json(out.trim_end()).unwrap();
    let result = obj_get(&doc, "result").expect("result key");
    assert!(obj_get(result, "comparison").is_some(), "{out}");
}

#[test]
fn scenario_study_trace_out_needs_single_style_children() {
    // The shipped study runs 8 replications: --trace-out refuses.
    let (_, err, ok) = airesim(&[
        "scenario",
        "--config",
        "configs/scenario_study.yaml",
        "--trace-out",
        "-",
    ]);
    assert!(!ok);
    assert!(err.contains("replications: 1"), "stderr: {err}");

    // With replications 1 it writes one labeled timeline per child.
    let cfg = std::env::temp_dir().join("airesim_study_trace.yaml");
    let text = std::fs::read_to_string("configs/scenario_study.yaml")
        .unwrap()
        .replace("replications: 8", "replications: 1");
    std::fs::write(&cfg, text).unwrap();
    let trace = std::env::temp_dir().join("airesim_study_trace.ndjson");
    let (_, err, ok) = airesim(&[
        "scenario",
        "--config",
        cfg.to_str().unwrap(),
        "--trace-out",
        trace.to_str().unwrap(),
    ]);
    let _ = std::fs::remove_file(&cfg);
    assert!(ok, "stderr: {err}");
    let timeline = std::fs::read_to_string(&trace).unwrap();
    let _ = std::fs::remove_file(&trace);
    let mut separators = 0;
    for line in timeline.trim_end().lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("bad line `{line}`: {e}"));
        if obj_get(&doc, "type") == Some(&Json::str("child-timeline")) {
            separators += 1;
        }
    }
    assert_eq!(separators, 4, "one separator per child");
}

#[test]
fn run_accepts_checkpoint_policy_overrides() {
    // young_daly needs a commit cost; the CLI surfaces the build error.
    let (_, err, ok) =
        airesim(&["run", "--set", SMALL, "--policy", "checkpoint=young_daly"]);
    assert!(!ok);
    assert!(err.contains("checkpoint_cost"), "stderr: {err}");

    // With the cost knob set it runs end to end.
    let (out, err, ok) = airesim(&[
        "run",
        "--seed",
        "7",
        "--set",
        &format!("{SMALL},checkpoint_cost=10"),
        "--policy",
        "checkpoint=young_daly",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("makespan"));
}

#[test]
fn checkpoint_scenario_runs_and_labels_policies() {
    // Scale the shipped config down (fewer reps) via a temp copy —
    // `replications:` is scenario metadata, not a `--set` param.
    let cfg = std::env::temp_dir().join("airesim_checkpoint_scenario.yaml");
    let text = std::fs::read_to_string("configs/scenario_checkpoint.yaml")
        .unwrap()
        .replace("replications: 8", "replications: 2");
    std::fs::write(&cfg, text).unwrap();
    let (out, err, ok) = airesim(&["scenario", "--config", cfg.to_str().unwrap()]);
    let _ = std::fs::remove_file(&cfg);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("policies.checkpoint=periodic"), "{out}");
    assert!(out.contains("policies.checkpoint=young_daly"), "{out}");
}

#[test]
fn run_accepts_policy_overrides() {
    let (out, err, ok) = airesim(&[
        "run",
        "--seed",
        "7",
        "--set",
        SMALL,
        "--policy",
        "selection=locality,repair=job_first",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("makespan"));

    let (_, err, ok) = airesim(&["run", "--set", SMALL, "--policy", "selection=bogus"]);
    assert!(!ok);
    assert!(err.contains("unknown selection policy"), "stderr: {err}");
}

#[test]
fn scenario_whatif_from_file() {
    let (out, err, ok) =
        airesim(&["scenario", "--config", "configs/scenario_recovery_whatif.yaml"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("selection=locality"), "{out}");
    assert!(out.contains("scaling recovery_time"), "{out}");
}

#[test]
fn scenario_inject_from_file() {
    let (out, err, ok) =
        airesim(&["scenario", "--config", "configs/scenario_incident_replay.yaml"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("StandbySwap"), "trace should show the swap: {out}");
    assert!(out.contains("failures"), "{out}");
}

#[test]
fn list_metrics_covers_the_registry() {
    let (out, _, ok) = airesim(&["list-metrics"]);
    assert!(ok);
    for m in [
        "makespan_hours",
        "failures_total",
        "utilization",
        "events_delivered",
        "domain_failures",
        "domain_max_blast",
        "domain_job_interruptions",
        "domain_downtime",
        "checkpoints_committed",
        "checkpoint_overhead",
        "goodput_fraction",
    ] {
        assert!(out.contains(m), "list-metrics missing {m}");
    }
    assert!(out.contains("unit"), "header missing: {out}");
}

#[test]
fn format_text_is_the_default_byte_for_byte() {
    let (plain, _, ok1) = airesim(&["run", "--seed", "7", "--set", SMALL]);
    let (explicit, _, ok2) =
        airesim(&["run", "--seed", "7", "--set", SMALL, "--format", "text"]);
    assert!(ok1 && ok2);
    assert_eq!(plain, explicit);
}

#[test]
fn run_format_json_parses_and_lists_metrics() {
    let (out, err, ok) =
        airesim(&["run", "--seed", "7", "--set", SMALL, "--format", "json"]);
    assert!(ok, "stderr: {err}");
    let doc = parse_json(out.trim_end()).unwrap_or_else(|e| panic!("{e}: {out}"));
    let Json::Obj(fields) = &doc else { panic!("expected object") };
    let metrics = &fields.iter().find(|(k, _)| k == "metrics").expect("metrics").1;
    let Json::Obj(m) = metrics else { panic!("metrics must be an object") };
    assert!(m.iter().any(|(k, _)| k == "makespan_hours"));
    assert!(m.iter().any(|(k, _)| k == "utilization"));
}

#[test]
fn sweep_format_csv_equals_legacy_csv_flag() {
    let base = [
        "sweep", "--param", "recovery_time", "--values", "10,30", "--reps", "2",
        "--seed", "5", "--set", SMALL,
    ];
    let mut with_flag = base.to_vec();
    with_flag.push("--csv");
    let mut with_format = base.to_vec();
    with_format.extend(["--format", "csv"]);
    let (a, _, ok1) = airesim(&with_flag);
    let (b, _, ok2) = airesim(&with_format);
    assert!(ok1 && ok2);
    assert_eq!(a, b, "--format csv must match the legacy --csv output");
}

#[test]
fn whatif_format_ndjson_lines_parse() {
    let (out, err, ok) = airesim(&[
        "whatif", "--param", "recovery_time", "--factor", "2", "--reps", "2",
        "--set", SMALL, "--format", "ndjson",
    ]);
    assert!(ok, "stderr: {err}");
    let lines: Vec<&str> = out.trim_end().lines().collect();
    assert_eq!(lines.len(), 3, "2 points + whatif summary: {out}");
    for line in &lines {
        parse_json(line).unwrap_or_else(|e| panic!("{e}: {line}"));
    }
    assert!(lines[2].contains("\"delta_pct\""), "{out}");
}

#[test]
fn scenario_format_json_parses() {
    let (out, err, ok) = airesim(&[
        "scenario", "--config", "configs/scenario_recovery_whatif.yaml",
        "--format", "json",
    ]);
    assert!(ok, "stderr: {err}");
    let doc = parse_json(out.trim_end()).unwrap_or_else(|e| panic!("{e}: {out}"));
    let Json::Obj(fields) = &doc else { panic!("expected object") };
    assert!(fields.iter().any(|(k, _)| k == "policies"));
    assert!(fields.iter().any(|(k, _)| k == "result"));
}

#[test]
fn scenario_policy_axis_sweep_labels_by_policy() {
    let (out, err, ok) =
        airesim(&["scenario", "--config", "configs/scenario_policy_axes.yaml"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("policies.selection=first_fit"), "{out}");
    assert!(out.contains("policies.selection=locality"), "{out}");
}

#[test]
fn run_trace_out_writes_ndjson_events() {
    let path = std::env::temp_dir().join("airesim_trace_out_test.ndjson");
    let path_s = path.to_str().unwrap();
    let (_, err, ok) = airesim(&[
        "run", "--seed", "7", "--set", SMALL, "--trace-out", path_s,
    ]);
    assert!(ok, "stderr: {err}");
    let content = std::fs::read_to_string(&path).expect("trace file written");
    let _ = std::fs::remove_file(&path);
    let mut saw_completion = false;
    for line in content.trim_end().lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("{e}: {line}"));
        let Json::Obj(fields) = &doc else { panic!("event must be an object") };
        assert!(fields.iter().any(|(k, _)| k == "at"));
        if fields.iter().any(|(k, v)| k == "event" && *v == Json::str("job_completed")) {
            saw_completion = true;
        }
    }
    assert!(saw_completion, "timeline must include job_completed: {content}");
}

#[test]
fn prescreen_rejects_policy_axes() {
    // The CTMC screen is policy-blind: a policies.* axis would rank
    // identical configs under different labels. Must refuse, not mislead.
    let (_, err, ok) = airesim(&[
        "prescreen", "--config", "configs/scenario_policy_axes.yaml",
        "--top", "1", "--reps", "1",
    ]);
    assert!(!ok);
    assert!(err.contains("policy-blind"), "stderr: {err}");
}

#[test]
fn scenario_topology_runs_and_labels_policies() {
    // Scale the shipped config down (fewer reps, shorter job) via a temp
    // copy — `replications:` is scenario metadata, not a `--set` param.
    let cfg = std::env::temp_dir().join("airesim_topo_scenario.yaml");
    let text = std::fs::read_to_string("configs/scenario_topology.yaml")
        .unwrap()
        .replace("replications: 8", "replications: 2")
        .replace("job_len: 4*1440", "job_len: 1440");
    std::fs::write(&cfg, text).unwrap();
    let (out, err, ok) = airesim(&["scenario", "--config", cfg.to_str().unwrap()]);
    let _ = std::fs::remove_file(&cfg);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("policies.selection=locality"), "{out}");
    assert!(out.contains("policies.selection=anti_affinity"), "{out}");
}

#[test]
fn run_trace_out_carries_domain_failure_events() {
    let path = std::env::temp_dir().join("airesim_domain_trace.ndjson");
    // The shipped topology config's params (4-day job, ~14 expected
    // switch outages) through plain `run`: the sweep: section is ignored
    // by this command, the topology: block is not.
    let (_, err, ok) = airesim(&[
        "run", "--seed", "7",
        "--config", "configs/scenario_topology.yaml",
        "--trace-out", path.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {err}");
    let content = std::fs::read_to_string(&path).expect("trace written");
    let _ = std::fs::remove_file(&path);
    let mut saw_domain = false;
    for line in content.trim_end().lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("{e}: {line}"));
        let Json::Obj(fields) = &doc else { panic!("object per line") };
        if fields.iter().any(|(k, v)| k == "event" && *v == Json::str("domain_failure")) {
            saw_domain = true;
            for key in ["level", "domain_id", "servers_hit"] {
                assert!(fields.iter().any(|(k, _)| k == key), "missing {key}: {line}");
            }
        }
    }
    assert!(saw_domain, "timeline must carry domain_failure events: {content}");
}

#[test]
fn prescreen_format_json_parses_and_text_is_default() {
    let base = [
        "prescreen", "--param", "recovery_time", "--values", "10,30",
        "--top", "1", "--reps", "2", "--set", SMALL,
    ];
    let (text_out, err, ok) = airesim(&base);
    assert!(ok, "stderr: {err}");
    assert!(text_out.contains("analytical ranking (best first):"), "{text_out}");
    assert!(text_out.contains("DES validation of the top 1"), "{text_out}");

    let mut with_json = base.to_vec();
    with_json.extend(["--format", "json"]);
    let (out, err, ok) = airesim(&with_json);
    assert!(ok, "stderr: {err}");
    let doc = parse_json(out.trim_end()).unwrap_or_else(|e| panic!("{e}: {out}"));
    let Json::Obj(fields) = &doc else { panic!("expected object") };
    assert!(fields.iter().any(|(k, v)| k == "kind" && *v == Json::str("prescreen")));
    assert!(fields.iter().any(|(k, _)| k == "ranking"));
    assert!(fields.iter().any(|(k, _)| k == "validated"));

    // csv/ndjson are not prescreen formats: clean refusal.
    let mut with_csv = base.to_vec();
    with_csv.extend(["--format", "csv"]);
    let (_, err, ok) = airesim(&with_csv);
    assert!(!ok);
    assert!(err.contains("text or json"), "stderr: {err}");
}

#[test]
fn scenario_trace_out_does_not_change_stdout() {
    // A scenario that does NOT ask for a printed trace must produce the
    // same stdout with and without --trace-out (the timeline goes to the
    // file only).
    let cfg = std::env::temp_dir().join("airesim_single_no_trace.yaml");
    std::fs::write(
        &cfg,
        "scenario: single\nseed: 7\nparams:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n",
    )
    .unwrap();
    let out_path = std::env::temp_dir().join("airesim_scenario_trace.ndjson");
    let cfg_s = cfg.to_str().unwrap();
    let (plain, _, ok1) = airesim(&["scenario", "--config", cfg_s]);
    let (with_trace_out, err, ok2) = airesim(&[
        "scenario", "--config", cfg_s, "--trace-out", out_path.to_str().unwrap(),
    ]);
    assert!(ok1 && ok2, "stderr: {err}");
    assert_eq!(plain, with_trace_out, "--trace-out must not leak into stdout");
    let timeline = std::fs::read_to_string(&out_path).expect("timeline written");
    let _ = std::fs::remove_file(&cfg);
    let _ = std::fs::remove_file(&out_path);
    assert!(!timeline.trim().is_empty());
    for line in timeline.trim_end().lines() {
        parse_json(line).unwrap_or_else(|e| panic!("{e}: {line}"));
    }
}

#[test]
fn bad_format_and_metric_are_rejected_cleanly() {
    let (_, err, ok) = airesim(&["run", "--set", SMALL, "--format", "xml"]);
    assert!(!ok);
    assert!(err.contains("unknown format"), "stderr: {err}");

    let (_, err, ok) = airesim(&[
        "sweep", "--param", "recovery_time", "--values", "10", "--reps", "1",
        "--set", SMALL, "--metric", "makespam",
    ]);
    assert!(!ok);
    assert!(err.contains("unknown metric"), "stderr: {err}");

    // Bad or conflicting sweep flags must fail before any simulation runs.
    let (_, err, ok) = airesim(&[
        "sweep", "--param", "recovery_time", "--values", "10", "--reps", "1",
        "--set", SMALL, "--format", "jsn",
    ]);
    assert!(!ok);
    assert!(err.contains("unknown format"), "stderr: {err}");
    let (_, err, ok) = airesim(&[
        "sweep", "--param", "recovery_time", "--values", "10", "--reps", "1",
        "--set", SMALL, "--csv", "--format", "json",
    ]);
    assert!(!ok);
    assert!(err.contains("mutually exclusive"), "stderr: {err}");
}

#[test]
fn bad_input_is_rejected_cleanly() {
    let (_, err, ok) = airesim(&["run", "--set", "bogus_param=1"]);
    assert!(!ok);
    assert!(err.contains("unknown parameter"));

    let (_, err, ok) = airesim(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("unknown subcommand"));

    let (_, err, ok) = airesim(&["run", "--set", "auto_repair_prob=1.5"]);
    assert!(!ok);
    assert!(err.contains("probability"), "stderr: {err}");
}
