//! CLI end-to-end tests: drive the `airesim` binary the way a user would.
//! (`CARGO_BIN_EXE_airesim` is provided by cargo for integration tests.)

use std::process::Command;

fn airesim(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_airesim"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

/// Small, fast override set reused across tests.
const SMALL: &str = "job_size=32,working_pool=40,spare_pool=8,warm_standbys=4,job_len=1440,random_failure_rate=0.5/1440,systematic_failure_rate=2.5/1440";

#[test]
fn help_lists_subcommands() {
    let (out, _, ok) = airesim(&["help"]);
    assert!(ok);
    for cmd in ["run", "sweep", "analytic", "whatif", "list-params"] {
        assert!(out.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn run_small_config() {
    let (out, err, ok) = airesim(&["run", "--seed", "7", "--set", SMALL]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("makespan"));
    assert!(out.contains("completed"));
    assert!(out.contains("true"));
}

#[test]
fn run_is_deterministic_across_invocations() {
    let (a, _, _) = airesim(&["run", "--seed", "11", "--set", SMALL]);
    let (b, _, _) = airesim(&["run", "--seed", "11", "--set", SMALL]);
    assert_eq!(a, b);
    let (c, _, _) = airesim(&["run", "--seed", "12", "--set", SMALL]);
    assert_ne!(a, c);
}

#[test]
fn trace_flag_prints_events() {
    let (out, _, ok) = airesim(&["run", "--seed", "7", "--trace", "--set", SMALL]);
    assert!(ok);
    assert!(out.contains("JobStarted"));
    assert!(out.contains("JobCompleted"));
}

#[test]
fn sweep_csv_output() {
    let (out, err, ok) = airesim(&[
        "sweep",
        "--param",
        "recovery_time",
        "--values",
        "10,30",
        "--reps",
        "2",
        "--csv",
        "--set",
        SMALL,
    ]);
    assert!(ok, "stderr: {err}");
    let lines: Vec<&str> = out.trim().lines().collect();
    assert_eq!(lines.len(), 3, "header + 2 rows: {out}");
    assert!(lines[0].starts_with("recovery_time,metric,n,mean"));
}

#[test]
fn sweep_from_config_file() {
    let (out, err, ok) = airesim(&[
        "sweep",
        "--config",
        "configs/fig2a.yaml",
        "--reps",
        "1",
        "--set",
        SMALL,
    ]);
    // Config replications (30) override --reps; that's documented — just
    // assert the grid shape appears.
    assert!(ok, "stderr: {err}");
    assert!(out.contains("recovery_time=10"));
    assert!(out.contains("working_pool=4192"));
}

#[test]
fn whatif_compares_factor() {
    let (out, err, ok) = airesim(&[
        "whatif",
        "--param",
        "recovery_time",
        "--factor",
        "2",
        "--reps",
        "2",
        "--set",
        SMALL,
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("changes mean training time by"));
}

#[test]
fn list_params_covers_table1() {
    let (out, _, ok) = airesim(&["list-params"]);
    assert!(ok);
    for p in ["recovery_time", "working_pool", "warm_standbys", "diagnosis_prob"] {
        assert!(out.contains(p));
    }
}

#[test]
fn analytic_rust_only() {
    let (out, err, ok) = airesim(&["analytic", "--rust-only"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("makespan_est"));
    assert!(out.contains("avail_avg"));
}

#[test]
fn list_policies_covers_every_axis() {
    let (out, _, ok) = airesim(&["list-policies"]);
    assert!(ok);
    for name in ["selection", "repair", "checkpoint", "failure", "locality", "job_first"] {
        assert!(out.contains(name), "list-policies missing {name}");
    }
}

#[test]
fn run_accepts_policy_overrides() {
    let (out, err, ok) = airesim(&[
        "run",
        "--seed",
        "7",
        "--set",
        SMALL,
        "--policy",
        "selection=locality,repair=job_first",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("makespan"));

    let (_, err, ok) = airesim(&["run", "--set", SMALL, "--policy", "selection=bogus"]);
    assert!(!ok);
    assert!(err.contains("unknown selection policy"), "stderr: {err}");
}

#[test]
fn scenario_whatif_from_file() {
    let (out, err, ok) =
        airesim(&["scenario", "--config", "configs/scenario_recovery_whatif.yaml"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("selection=locality"), "{out}");
    assert!(out.contains("scaling recovery_time"), "{out}");
}

#[test]
fn scenario_inject_from_file() {
    let (out, err, ok) =
        airesim(&["scenario", "--config", "configs/scenario_incident_replay.yaml"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("StandbySwap"), "trace should show the swap: {out}");
    assert!(out.contains("failures"), "{out}");
}

#[test]
fn bad_input_is_rejected_cleanly() {
    let (_, err, ok) = airesim(&["run", "--set", "bogus_param=1"]);
    assert!(!ok);
    assert!(err.contains("unknown parameter"));

    let (_, err, ok) = airesim(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("unknown subcommand"));

    let (_, err, ok) = airesim(&["run", "--set", "auto_repair_prob=1.5"]);
    assert!(!ok);
    assert!(err.contains("probability"), "stderr: {err}");
}
