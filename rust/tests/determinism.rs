//! Determinism / replay guarantees: a simulation is a pure function of
//! (params, seed).

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::sim::rng::Rng;
use airesim::sweep::{run_sweep, Sweep};

fn outputs_fingerprint(p: &Params, seed: u64) -> (f64, u64, u64, u64, u64) {
    let o = Simulation::new(p, seed).run();
    (o.makespan, o.failures_total, o.preemptions, o.repairs_auto, o.repairs_manual)
}

#[test]
fn same_seed_same_everything() {
    let p = Params::small_test();
    for seed in [1, 7, 42, 1234] {
        assert_eq!(outputs_fingerprint(&p, seed), outputs_fingerprint(&p, seed));
    }
}

#[test]
fn same_seed_same_trace() {
    let p = Params::small_test();
    let (_, t1) = Simulation::new(&p, 9).with_trace().run_traced();
    let (_, t2) = Simulation::new(&p, 9).with_trace().run_traced();
    assert_eq!(t1.records, t2.records);
    assert!(!t1.is_empty());
}

#[test]
fn different_seeds_differ() {
    let p = Params::small_test();
    let a = outputs_fingerprint(&p, 1);
    let b = outputs_fingerprint(&p, 2);
    assert_ne!(a, b, "two seeds gave identical runs (astronomically unlikely)");
}

#[test]
fn derived_streams_reproduce_sweep_points() {
    // Replication (i, r) only depends on (seed, i, r): re-running a single
    // point standalone reproduces the sweep's value for that point.
    let p = Params::small_test();
    let sweep = Sweep::one_way("d", "recovery_time", &[10.0, 20.0, 30.0], 3, 99);
    let result = run_sweep(&p, &sweep, 0);

    let mut p1 = p.clone();
    p1.recovery_time = 20.0;
    let standalone =
        Simulation::with_rng(&p1, Rng::derived(99, &[1, 2])).run().makespan;
    let from_sweep = result.points[1].collector.values("makespan").unwrap();
    assert!(
        from_sweep.contains(&standalone),
        "sweep values {from_sweep:?} missing standalone {standalone}"
    );
}

#[test]
fn per_server_and_gang_paths_agree_statistically() {
    // The exponential gang fast path must match the per-server clock path
    // in distribution: compare mean makespan over replications.
    let mut p = Params::small_test();
    p.job_size = 32;
    p.working_pool = 40;
    p.warm_standbys = 4;
    p.spare_pool = 8;
    p.job_len = 2880.0;
    let reps = 60;
    let mean = |fast: bool| -> f64 {
        (0..reps)
            .map(|r| {
                let sim = Simulation::with_rng(&p, Rng::derived(7, &[fast as u64, r]));
                let sim = if fast { sim } else { sim.with_per_server_clocks() };
                sim.run().makespan
            })
            .sum::<f64>()
            / reps as f64
    };
    let m_fast = mean(true);
    let m_slow = mean(false);
    let rel = (m_fast - m_slow).abs() / m_slow;
    assert!(
        rel < 0.05,
        "gang vs per-server makespan means diverge: {m_fast} vs {m_slow} ({rel:.3})"
    );
}
