//! The shipped `configs/*.yaml` files must parse, validate, and drive real
//! (scaled-down) sweeps — executable documentation stays correct.

use airesim::config::{validate, yaml};
use airesim::sweep::{run_sweep, sweep_from_doc, AxisValue};

fn load(path: &str) -> yaml::Value {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path}: {e}"));
    yaml::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"))
}

#[test]
fn table1_defaults_yaml_equals_builtin() {
    let doc = load("configs/table1_defaults.yaml");
    let p = validate::params_from_config(&doc).expect("valid");
    let builtin = airesim::config::Params::table1_defaults();
    for name in airesim::config::Params::sweepable_names() {
        let a = p.get_by_name(name).unwrap();
        let b = builtin.get_by_name(name).unwrap();
        assert!(
            (a - b).abs() <= 1e-12 * b.abs().max(1.0),
            "{name}: yaml {a} != builtin {b}"
        );
    }
}

#[test]
fn fig2a_yaml_builds_the_paper_grid() {
    let doc = load("configs/fig2a.yaml");
    validate::params_from_config(&doc).expect("params valid");
    let sweep = sweep_from_doc(&doc, 1, 1).expect("sweep");
    assert_eq!(sweep.points.len(), 12);
    assert_eq!(sweep.replications, 30);
    assert_eq!(sweep.master_seed, 42);
    let num = |name: &str, v: f64| (name.to_string(), AxisValue::Num(v));
    assert_eq!(sweep.points[0].overrides[0], num("recovery_time", 10.0));
    assert_eq!(sweep.points[0].overrides[1], num("working_pool", 4112.0));
    assert_eq!(sweep.points[11].overrides[0], num("recovery_time", 30.0));
    assert_eq!(sweep.points[11].overrides[1], num("working_pool", 4192.0));
}

#[test]
fn fig2b_yaml_builds_the_paper_grid() {
    let doc = load("configs/fig2b.yaml");
    let sweep = sweep_from_doc(&doc, 1, 1).expect("sweep");
    assert_eq!(sweep.points.len(), 12);
    assert_eq!(sweep.points[0].overrides[0].0, "waiting_time");
}

#[test]
fn aging_fleet_yaml_runs_scaled_down() {
    let doc = load("configs/aging_fleet.yaml");
    let mut p = validate::params_from_config(&doc).expect("params valid");
    assert_eq!(p.retirement_threshold, 3);
    assert!(p.bad_regen_interval > 0.0);
    assert!(matches!(
        p.failure_dist,
        airesim::config::DistKind::Weibull { .. }
    ));
    // Scale the cluster down so the test is fast, keep the mechanics.
    p.job_size = 32;
    p.warm_standbys = 4;
    p.working_pool = 40;
    p.spare_pool = 8;
    p.job_len = 2.0 * 1440.0;
    p.bad_regen_interval = 300.0;
    p.bad_regen_fraction = 0.05;
    p.random_failure_rate = 1.0 / 1440.0;
    p.systematic_failure_rate = 10.0 / 1440.0;
    p.max_sim_time = 1e7;

    let mut sweep = sweep_from_doc(&doc, 1, 1).expect("sweep");
    sweep.replications = 2;
    let result = run_sweep(&p, &sweep, 0);
    assert_eq!(result.points.len(), 4); // thresholds [0, 2, 3, 5]
    for pr in &result.points {
        let s = pr.summary("completed").unwrap();
        assert_eq!(s.n, 2);
    }
    // Threshold 0 never retires; low thresholds retire more than high.
    let retirements: Vec<f64> = result
        .points
        .iter()
        .map(|p| p.summary("retirements").unwrap().mean)
        .collect();
    assert_eq!(retirements[0], 0.0, "threshold 0 must not retire");
    assert!(
        retirements[1] >= retirements[3],
        "threshold 2 should retire at least as many as threshold 5: {retirements:?}"
    );
}

#[test]
fn scenario_workload_yaml_runs_scaled_down() {
    let doc = load("configs/scenario_workload.yaml");
    let p = validate::params_from_config(&doc).expect("params valid");
    let w = p.workload.as_ref().expect("workload block present");
    assert!(!w.is_replay());
    assert_eq!(w.classes.len(), 2);
    assert_eq!(p.num_jobs, 4);

    let mut sweep = sweep_from_doc(&doc, 1, 1).expect("sweep");
    assert_eq!(sweep.points.len(), 6); // 3 repair disciplines x 2 capacities
    sweep.replications = 2;
    let result = run_sweep(&p, &sweep, 0);
    for pr in &result.points {
        let arrived = pr.summary("jobs_arrived").unwrap();
        assert_eq!(arrived.n, 2);
        assert!(arrived.mean > 0.0, "arrivals must be delivered");
        let admitted = pr.summary("jobs_admitted").unwrap();
        assert!(
            admitted.mean <= arrived.mean,
            "admitted {} > arrived {}",
            admitted.mean,
            arrived.mean
        );
    }
}

#[test]
fn artifact_contract_matches_rust_mirror() {
    // The AOT step writes artifacts/analytic.hlo.json describing the
    // parameter/output columns; the Rust mirror must agree. (Gated on the
    // artifact having been built.)
    let path = "artifacts/analytic.hlo.json";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} not built (run `make artifacts`)");
        return;
    }
    let text = std::fs::read_to_string(path).unwrap();
    for name in airesim::analytical::PARAM_NAMES {
        assert!(text.contains(&format!("\"{name}\"")), "param {name} missing from contract");
    }
    for name in airesim::analytical::OUTPUT_NAMES {
        assert!(text.contains(&format!("\"{name}\"")), "output {name} missing from contract");
    }
    assert!(text.contains("\"batch\": 64"));
    assert!(text.contains("\"n_params\": 16"));
    assert!(text.contains("\"n_outputs\": 8"));
}
