//! Acceptance suite for the structured observability API.
//!
//! * the **text** sink is byte-identical to the pre-redesign CLI output
//!   (the oracles below are literal copies of the legacy format strings,
//!   NOT calls into the sink code — divergence fails the test);
//! * the **json/ndjson** sinks emit valid JSON that round-trips every
//!   metric in the registry (parsed with `testkit::parse_json`, an
//!   independent reader);
//! * the **csv** sink's sweep table is the legacy `--csv` output;
//! * policy-axis sweeps label their points by policy name end-to-end;
//! * the Observer hook sees the exact event stream without perturbing
//!   the run.

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::model::{PolicySpec, RunOutputs};
use airesim::report::json::Json;
use airesim::report::{Format, RunRecord, Sink, SweepRecord, WhatIfRecord};
use airesim::scenario::{Scenario, ScenarioOutcome};
use airesim::stats::metrics;
use airesim::sweep::{run_sweep, Sweep};
use airesim::testkit::parse_json;
use airesim::trace::{Observer, Shared, Trace, TraceKind};
use std::cell::RefCell;
use std::rc::Rc;

fn small_run(seed: u64) -> RunRecord {
    let p = Params::small_test();
    let outputs = Simulation::new(&p, seed).run();
    RunRecord {
        seed,
        params: p,
        policies: PolicySpec::default(),
        outputs,
        trace: Trace::default(),
    }
}

fn small_sweep() -> SweepRecord {
    let base = Params::small_test();
    let sweep = Sweep::one_way("t", "recovery_time", &[10.0, 30.0], 3, 7);
    SweepRecord::new(run_sweep(&base, &sweep, 2), "makespan_hours")
}

fn obj_get<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

fn obj_keys(j: &Json) -> Vec<&str> {
    match j {
        Json::Obj(fields) => fields.iter().map(|(k, _)| k.as_str()).collect(),
        _ => Vec::new(),
    }
}

// ------------------------------------------------------------------ //
// Text byte-identity (literal legacy oracles)
// ------------------------------------------------------------------ //

/// Literal copy of the pre-redesign `cmd_run` println! sequence.
fn legacy_run_text(seed: u64, p: &Params, out: &RunOutputs) -> String {
    let mut s = String::new();
    s.push_str(&format!("== run outputs (seed {seed}) ==\n"));
    s.push_str(&format!(
        "makespan           {:>14.2} min ({:.2} days)\n",
        out.makespan,
        out.makespan / 1440.0
    ));
    s.push_str(&format!("completed          {:>14}\n", out.completed));
    s.push_str(&format!(
        "failures           {:>14} (random {}, systematic {})\n",
        out.failures_total, out.failures_random, out.failures_systematic
    ));
    s.push_str(&format!("standby swaps      {:>14}\n", out.standby_swaps));
    s.push_str(&format!("host selections    {:>14}\n", out.host_selections));
    s.push_str(&format!("preemptions        {:>14}\n", out.preemptions));
    s.push_str(&format!(
        "repairs            {:>14} auto, {} manual\n",
        out.repairs_auto, out.repairs_manual
    ));
    s.push_str(&format!("retirements        {:>14}\n", out.retirements));
    s.push_str(&format!("stall time         {:>14.2} min\n", out.stall_time));
    s.push_str(&format!("recovery total     {:>14.2} min\n", out.recovery_total));
    s.push_str(&format!("avg run duration   {:>14.2} min\n", out.avg_run_duration));
    s.push_str(&format!("utilization        {:>14.4}\n", out.utilization(p.job_len)));
    s.push_str(&format!("events delivered   {:>14}\n", out.events_delivered));
    s
}

/// Literal copy of the pre-redesign `Scenario::render` for single runs.
fn legacy_scenario_single_text(sc: &Scenario, out: &RunOutputs) -> String {
    let mut s = format!(
        "== scenario: {} [single] ==\npolicies: selection={} repair={} checkpoint={} failure={}\n",
        sc.title, sc.policies.selection, sc.policies.repair, sc.policies.checkpoint,
        sc.policies.failure,
    );
    s.push_str(&format!(
        "makespan           {:>14.2} min ({:.2} days)\n\
         completed          {:>14}\n\
         failures           {:>14} (random {}, systematic {})\n\
         standby swaps      {:>14}\n\
         host selections    {:>14}\n\
         preemptions        {:>14}\n\
         repairs            {:>14} auto, {} manual\n\
         stall time         {:>14.2} min\n\
         utilization        {:>14.4}\n",
        out.makespan,
        out.makespan / 1440.0,
        out.completed,
        out.failures_total,
        out.failures_random,
        out.failures_systematic,
        out.standby_swaps,
        out.host_selections,
        out.preemptions,
        out.repairs_auto,
        out.repairs_manual,
        out.stall_time,
        out.utilization(sc.params.job_len)
    ));
    s
}

#[test]
fn text_sink_run_is_byte_identical_to_legacy_cli() {
    let rec = small_run(7);
    let got = Format::Text.sink().run(&rec);
    let want = legacy_run_text(7, &rec.params, &rec.outputs);
    assert_eq!(got, want);
}

#[test]
fn text_sink_scenario_single_is_byte_identical_to_legacy_render() {
    let text = "scenario: single\nseed: 9\nparams:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let outcome = sc.run().unwrap();
    let ScenarioOutcome::Single { outputs, .. } = &outcome else { panic!() };
    let got = sc.render(&outcome);
    assert_eq!(got, legacy_scenario_single_text(&sc, outputs));
}

#[test]
fn text_sink_whatif_is_byte_identical_to_legacy() {
    let base = Params::small_test();
    let sweep = Sweep::one_way("what-if: recovery_time x2", "recovery_time", &[20.0, 40.0], 3, 5);
    let result = run_sweep(&base, &sweep, 1);
    // Legacy: text_table + the scaling line built from the two summaries.
    let a = result.points[0].summary("makespan_hours").unwrap();
    let b = result.points[1].summary("makespan_hours").unwrap();
    let want = format!(
        "{}\nscaling recovery_time by 2 changes mean training time by {:+.2}% ({:.1}h -> {:.1}h)\n",
        airesim::report::text_table(&result, "makespan_hours"),
        (b.mean / a.mean - 1.0) * 100.0,
        a.mean,
        b.mean
    );
    let rec = WhatIfRecord {
        result,
        param: "recovery_time".into(),
        factor: 2.0,
        metric: "makespan_hours".into(),
    };
    assert_eq!(Format::Text.sink().whatif(&rec), want);
}

#[test]
fn csv_sink_sweep_is_the_legacy_csv() {
    let rec = small_sweep();
    let got = Format::Csv.sink().sweep(&rec);
    assert_eq!(got, airesim::report::csv(&rec.result, &rec.metric));
    let lines: Vec<&str> = got.trim_end().lines().collect();
    assert_eq!(lines.len(), 3);
    assert!(lines[0].starts_with("recovery_time,metric,n,mean"));
    assert!(lines[1].starts_with("10,makespan_hours,3,"));
}

// ------------------------------------------------------------------ //
// JSON / NDJSON round-trips
// ------------------------------------------------------------------ //

#[test]
fn json_run_round_trips_every_registry_metric() {
    let rec = small_run(11);
    let doc = parse_json(Format::Json.sink().run(&rec).trim_end()).unwrap();
    let metrics_obj = obj_get(&doc, "metrics").expect("metrics key");
    let keys = obj_keys(metrics_obj);
    let want: Vec<&str> = metrics::names().collect();
    assert_eq!(keys, want, "JSON must carry every registry metric, in order");
    for (m, v) in rec.metric_values() {
        let entry = obj_get(metrics_obj, m.name).unwrap();
        let Some(Json::Num(got)) = obj_get(entry, "value") else {
            panic!("{} has no numeric value", m.name)
        };
        assert_eq!(*got, v, "{} round-trip", m.name);
        assert_eq!(obj_get(entry, "unit"), Some(&Json::str(m.unit)));
    }
    let policies = obj_get(&doc, "policies").unwrap();
    assert_eq!(obj_get(policies, "selection"), Some(&Json::str("first_fit")));
}

#[test]
fn json_sweep_carries_full_summaries_for_every_metric() {
    let rec = small_sweep();
    let doc = parse_json(Format::Json.sink().sweep(&rec).trim_end()).unwrap();
    let Some(Json::Arr(points)) = obj_get(&doc, "points") else { panic!("points") };
    assert_eq!(points.len(), 2);
    for (i, point) in points.iter().enumerate() {
        let metrics_obj = obj_get(point, "metrics").unwrap();
        assert_eq!(obj_keys(metrics_obj), metrics::names().collect::<Vec<_>>());
        let s = rec.result.points[i].summary("makespan").unwrap();
        let ms = obj_get(metrics_obj, "makespan").unwrap();
        assert_eq!(obj_get(ms, "n"), Some(&Json::Num(s.n as f64)));
        assert_eq!(obj_get(ms, "mean"), Some(&Json::Num(s.mean)));
        assert_eq!(obj_get(ms, "p95"), Some(&Json::Num(s.p95)));
    }
}

#[test]
fn ndjson_run_lines_each_parse() {
    let p = Params::small_test();
    let (outputs, trace) = Simulation::new(&p, 13).with_trace().run_traced();
    let rec = RunRecord {
        seed: 13,
        params: p,
        policies: PolicySpec::default(),
        outputs,
        trace,
    };
    let out = Format::Ndjson.sink().run(&rec);
    let mut events = 0;
    let mut metric_lines = 0;
    for line in out.trim_end().lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("bad line `{line}`: {e}"));
        match obj_get(&doc, "type") {
            Some(Json::Str(t)) if t == "event" => events += 1,
            Some(Json::Str(t)) if t == "metric" => metric_lines += 1,
            other => panic!("unexpected type {other:?}"),
        }
    }
    assert_eq!(metric_lines, metrics::REGISTRY.len());
    assert_eq!(events, rec.trace.len());
    assert!(events > 0, "a traced run must produce event lines");
}

#[test]
fn ndjson_and_json_agree_on_scenario_sweeps() {
    let text = "scenario: sweep\nseed: 3\nreplications: 2\n\
                params:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n\
                sweep:\n  kind: one_way\n  x: { name: recovery_time, values: [10, 30] }\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let outcome = sc.run().unwrap();
    let record = sc.record(&outcome);

    let json_doc = parse_json(Format::Json.sink().scenario(&record).trim_end()).unwrap();
    assert_eq!(obj_get(&json_doc, "scenario"), Some(&Json::str("sweep")));
    let result = obj_get(&json_doc, "result").unwrap();
    let Some(Json::Arr(points)) = obj_get(result, "points") else { panic!() };
    assert_eq!(points.len(), 2);

    let nd = Format::Ndjson.sink().scenario(&record);
    let lines: Vec<&str> = nd.trim_end().lines().collect();
    assert_eq!(lines.len(), 3, "meta line + 2 points: {nd}");
    let meta = parse_json(lines[0]).unwrap();
    assert_eq!(obj_get(&meta, "type"), Some(&Json::str("scenario")));
    for line in &lines[1..] {
        let doc = parse_json(line).unwrap();
        assert_eq!(obj_get(&doc, "type"), Some(&Json::str("point")));
    }
}

#[test]
fn compare_scenario_renders_in_all_formats() {
    let text = "scenario: compare\nseed: 6\nreplications: 3\n\
                params:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let outcome = sc.run().unwrap();
    let record = sc.record(&outcome);
    let doc = parse_json(Format::Json.sink().scenario(&record).trim_end()).unwrap();
    let result = obj_get(&doc, "result").unwrap();
    assert!(obj_get(result, "analytic").is_some());
    assert!(obj_get(result, "des_makespan").is_some());
    let text_out = Format::Text.sink().scenario(&record);
    assert!(text_out.contains("CTMC makespan_est"));
    let csv_out = Format::Csv.sink().scenario(&record);
    assert!(csv_out.starts_with("quantity,value\n"));
    for line in Format::Ndjson.sink().scenario(&record).trim_end().lines() {
        parse_json(line).unwrap();
    }
}

/// The topology subsystem added five `domain_*` registry metrics; the
/// pinned legacy text tables must not grow them. A run WITH a topology
/// still renders the exact legacy oracle (text reports a fixed hand-made
/// block, never the registry), and a run WITHOUT one is bit-for-bit the
/// pre-topology output by construction (`Params::small_test` carries no
/// topology — every oracle test above already pins that path).
#[test]
fn topology_runs_render_the_same_legacy_text_block() {
    let mut p = Params::small_test();
    p.topology = Some(airesim::config::TopologySpec {
        levels: vec![airesim::config::TopologyLevelSpec {
            name: "rack".into(),
            size: 8,
            outage_rate: 0.002 / 1440.0,
        }],
    });
    let outputs = Simulation::from_spec(&p, &PolicySpec::default(), airesim::sim::rng::Rng::new(7))
        .unwrap()
        .run();
    let rec = RunRecord {
        seed: 7,
        params: p,
        policies: PolicySpec::default(),
        outputs,
        trace: Trace::default(),
    };
    let got = Format::Text.sink().run(&rec);
    assert_eq!(got, legacy_run_text(7, &rec.params, &rec.outputs));
    assert!(!got.contains("domain"), "domain metrics stay out of the legacy table");
    // The machine sinks DO carry them, with units.
    let json = Format::Json.sink().run(&rec);
    for m in ["domain_failures", "domain_max_blast", "domain_downtime"] {
        assert!(json.contains(&format!("\"{m}\"")), "json missing {m}");
    }
}

// ------------------------------------------------------------------ //
// Policy axes end-to-end
// ------------------------------------------------------------------ //

#[test]
fn policy_axis_scenario_sweep_labels_points_by_policy() {
    let text = "scenario: sweep\nseed: 5\nreplications: 2\ntitle: selection shootout\n\
                params:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n\
                sweep:\n  kind: two_way\n  x: { name: policies.selection, values: [first_fit, locality] }\n  y: { name: recovery_time, values: [10, 30] }\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let outcome = sc.run().unwrap();
    let ScenarioOutcome::Sweep(result) = &outcome else { panic!("expected sweep") };
    assert_eq!(result.points.len(), 4);
    let labels: Vec<String> = result.points.iter().map(|p| p.point.label()).collect();
    assert_eq!(
        labels,
        vec![
            "policies.selection=first_fit, recovery_time=10",
            "policies.selection=first_fit, recovery_time=30",
            "policies.selection=locality, recovery_time=10",
            "policies.selection=locality, recovery_time=30",
        ]
    );
    for pr in &result.points {
        assert_eq!(pr.summary("makespan").unwrap().n, 2, "every point ran");
    }
    // The text table and CSV both carry the policy labels.
    let rendered = sc.render(&outcome);
    assert!(rendered.contains("policies.selection=locality, recovery_time=30"), "{rendered}");
    let csv = Format::Csv.sink().scenario(&sc.record(&outcome));
    assert!(csv.lines().next().unwrap().starts_with("policies.selection,recovery_time,"), "{csv}");
    assert!(csv.contains("\nlocality,30,"), "{csv}");
}

#[test]
fn policy_axis_point_equals_fixed_policy_run() {
    // A policy-axis point must behave exactly like the same policy set
    // passed via `with_policies` (same derived streams, same outputs).
    let base = Params::small_test();
    let axis = Sweep::from_axes(
        "axis",
        &[("policies.selection".to_string(), vec!["locality".into()])],
        3,
        17,
    );
    let fixed = Sweep::one_way("fixed", "recovery_time", &[base.recovery_time], 3, 17)
        .with_policies(PolicySpec {
            selection: "locality".into(),
            ..PolicySpec::default()
        });
    let ra = run_sweep(&base, &axis, 1);
    let rf = run_sweep(&base, &fixed, 1);
    for metric in ["makespan", "failures_total", "events_delivered"] {
        assert_eq!(
            ra.points[0].summary(metric).unwrap(),
            rf.points[0].summary(metric).unwrap(),
            "{metric} diverged between axis and fixed policy"
        );
    }
}

// ------------------------------------------------------------------ //
// Observer hook
// ------------------------------------------------------------------ //

struct Counter {
    events: usize,
    failures: usize,
}

impl Observer for Counter {
    fn observe(&mut self, _at: f64, kind: &TraceKind) {
        self.events += 1;
        if matches!(kind, TraceKind::Failure { .. }) {
            self.failures += 1;
        }
    }
}

#[test]
fn observer_sees_the_exact_trace_without_perturbing_the_run() {
    let p = Params::small_test();
    let baseline = Simulation::new(&p, 21).run();

    // Observer + trace together: the observer must see exactly the
    // trace's records, and the outputs must match the unobserved run.
    let counter = Rc::new(RefCell::new(Counter { events: 0, failures: 0 }));
    let (outputs, trace) = Simulation::new(&p, 21)
        .with_trace()
        .with_observer(Box::new(Shared(counter.clone())))
        .run_traced();
    assert_eq!(outputs, baseline, "observer must not perturb the run");
    assert_eq!(counter.borrow().events, trace.len());
    assert_eq!(
        counter.borrow().failures as u64,
        outputs.failures_total,
        "failure events mirror the failure count"
    );

    // Observer alone (no trace buffer): same stream, same outputs.
    let solo = Rc::new(RefCell::new(Counter { events: 0, failures: 0 }));
    let alone = Simulation::new(&p, 21)
        .with_observer(Box::new(Shared(solo.clone())))
        .run();
    assert_eq!(alone, baseline);
    assert_eq!(solo.borrow().events, trace.len());
}

#[test]
fn event_log_ndjson_matches_trace_ndjson() {
    let p = Params::small_test();
    let log = Rc::new(RefCell::new(Trace::default()));
    let (_, trace) = Simulation::new(&p, 23)
        .with_trace()
        .with_observer(Box::new(Shared(log.clone())))
        .run_traced();
    assert_eq!(log.borrow().to_ndjson(), trace.to_ndjson());
    for line in log.borrow().to_ndjson().trim_end().lines() {
        let doc = parse_json(line).unwrap();
        assert!(obj_get(&doc, "at").is_some());
        assert!(obj_get(&doc, "event").is_some());
    }
}
