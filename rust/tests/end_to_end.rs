//! End-to-end scenario tests against analytically-known expectations.

use airesim::config::{DistKind, Params};
use airesim::model::cluster::Simulation;
use airesim::sim::rng::Rng;

fn mean_over(p: &Params, reps: u64, f: impl Fn(&airesim::model::RunOutputs) -> f64) -> f64 {
    (0..reps)
        .map(|r| f(&Simulation::with_rng(p, Rng::derived(11, &[r])).run()))
        .sum::<f64>()
        / reps as f64
}

#[test]
fn failure_count_matches_rate_when_repairs_instant() {
    // With instant, always-successful repairs and all-good servers, the
    // gang always computes at job_size servers: E[failures] ≈ N * λ * L.
    let mut p = Params::small_test();
    p.systematic_fraction = 0.0;
    p.systematic_failure_rate = 0.0;
    p.random_failure_rate = 1.0 / 1440.0; // 1/day/server
    p.job_size = 50;
    p.warm_standbys = 5;
    p.working_pool = 60;
    p.spare_pool = 20;
    p.job_len = 5.0 * 1440.0;
    p.recovery_time = 0.0;
    p.host_selection_time = 0.0;
    p.auto_repair_time = 1e-3;
    p.auto_repair_prob = 1.0;
    p.auto_repair_fail_prob = 0.0;
    p.diagnosis_prob = 1.0;
    p.max_sim_time = 1e9;

    let want = 50.0 * (1.0 / 1440.0) * p.job_len; // = 250
    let got = mean_over(&p, 20, |o| o.failures_total as f64);
    let rel = (got - want).abs() / want;
    assert!(rel < 0.1, "failures {got:.1} vs expected {want:.1}");
}

#[test]
fn makespan_decomposition_zero_cost_recovery() {
    // With recovery/selection zero-cost, makespan == job_len (+ stalls,
    // which can't happen with instant repairs).
    let mut p = Params::small_test();
    p.recovery_time = 0.0;
    p.host_selection_time = 0.0;
    p.waiting_time = 0.0;
    p.auto_repair_time = 1e-3;
    p.auto_repair_prob = 1.0;
    p.auto_repair_fail_prob = 0.0;
    p.max_sim_time = 1e9;
    let got = mean_over(&p, 10, |o| o.makespan);
    assert!(
        (got - p.job_len).abs() < 1.0,
        "zero-cost failures must not stretch the job: {got} vs {}",
        p.job_len
    );
}

#[test]
fn bad_servers_drive_failure_mix() {
    // With a strong systematic rate, most failures should be systematic
    // early on; over long horizons repairs convert bad→good and the mix
    // shifts — here we only assert the presence of both kinds.
    let mut p = Params::small_test();
    p.systematic_fraction = 0.3;
    p.systematic_failure_rate = 20.0 / 1440.0;
    p.random_failure_rate = 0.5 / 1440.0;
    p.max_sim_time = 1e9;
    let o = Simulation::new(&p, 3).run();
    assert!(o.failures_systematic > 0);
    assert!(o.failures_random > 0);
    assert!(
        o.failures_systematic > o.failures_random,
        "systematic {} should dominate random {}",
        o.failures_systematic,
        o.failures_random
    );
}

#[test]
fn repairs_reduce_systematic_pressure_over_time() {
    // Compare total failures with repairs that always fix vs never fix:
    // fixing must yield fewer systematic failures.
    let mut base = Params::small_test();
    base.systematic_fraction = 0.3;
    base.systematic_failure_rate = 10.0 / 1440.0;
    base.job_len = 10.0 * 1440.0;
    base.max_sim_time = 1e9;

    let mut fixing = base.clone();
    fixing.auto_repair_fail_prob = 0.0;
    fixing.manual_repair_fail_prob = 0.0;

    let mut broken = base.clone();
    broken.auto_repair_fail_prob = 1.0;
    broken.manual_repair_fail_prob = 1.0;

    let f = mean_over(&fixing, 10, |o| o.failures_systematic as f64);
    let b = mean_over(&broken, 10, |o| o.failures_systematic as f64);
    assert!(
        f < b,
        "fixing repairs should reduce systematic failures: {f} !< {b}"
    );
}

#[test]
fn weibull_and_lognormal_families_run_to_completion() {
    for dist in [
        DistKind::Weibull { shape: 1.5 },
        DistKind::LogNormal { sigma: 0.8 },
    ] {
        let mut p = Params::small_test();
        p.failure_dist = dist;
        p.max_sim_time = 1e9;
        let o = Simulation::new(&p, 4).run();
        assert!(o.completed, "{dist:?} run did not complete");
        assert!(o.failures_total > 0, "{dist:?} produced no failures");
    }
}

#[test]
fn warm_standbys_reduce_host_selections() {
    let mut none = Params::small_test();
    none.warm_standbys = 0;
    let mut many = Params::small_test();
    many.warm_standbys = 8;
    many.working_pool = none.working_pool; // same pool, different allotment
    let hs_none = mean_over(&none, 10, |o| o.host_selections as f64);
    let hs_many = mean_over(&many, 10, |o| o.host_selections as f64);
    assert!(
        hs_many < hs_none,
        "standbys should absorb failures: {hs_many} !< {hs_none}"
    );
}

#[test]
fn bad_regen_increases_failures() {
    let mut base = Params::small_test();
    base.systematic_fraction = 0.0; // start clean
    base.job_len = 5.0 * 1440.0;
    base.max_sim_time = 1e9;
    let mut regen = base.clone();
    regen.bad_regen_interval = 1440.0;
    regen.bad_regen_fraction = 0.05;

    let f_base = mean_over(&base, 10, |o| o.failures_total as f64);
    let f_regen = mean_over(&regen, 10, |o| o.failures_total as f64);
    assert!(
        f_regen > f_base,
        "regeneration should add systematic failures: {f_regen} !> {f_base}"
    );
}

#[test]
fn checkpoint_interval_lengthens_jobs() {
    let mut cont = Params::small_test();
    cont.checkpoint_interval = 0.0;
    let mut coarse = cont.clone();
    coarse.checkpoint_interval = 120.0; // 2h checkpoints
    coarse.max_sim_time = 1e9;

    let m_cont = mean_over(&cont, 10, |o| o.makespan);
    let m_coarse = mean_over(&coarse, 10, |o| o.makespan);
    let lost = mean_over(&coarse, 10, |o| o.work_lost);
    assert!(lost > 0.0, "coarse checkpoints must lose work");
    assert!(
        m_coarse > m_cont,
        "losing work must lengthen the job: {m_coarse} !> {m_cont}"
    );
    // Continuous checkpointing loses nothing.
    assert_eq!(mean_over(&cont, 5, |o| o.work_lost), 0.0);
}

#[test]
fn horizon_stops_unfinishable_job() {
    let mut p = Params::small_test();
    p.working_pool = 64;
    p.warm_standbys = 0;
    p.spare_pool = 0;
    p.auto_repair_time = 1e12; // repairs never return
    p.manual_repair_time = 1e12;
    p.random_failure_rate = 10.0 / 1440.0; // fail fast
    p.max_sim_time = 30.0 * 1440.0;
    let o = Simulation::new(&p, 5).run();
    assert!(!o.completed);
    assert_eq!(o.makespan, p.max_sim_time);
    assert!(o.stall_time > 0.0, "job should die stalled");
}

#[test]
fn preemption_cost_accounted() {
    let mut p = Params::small_test();
    p.working_pool = 60; // below job_size: forces preemptions at t=0
    p.spare_pool = 16;
    p.preemption_cost = 7.5;
    let o = Simulation::new(&p, 6).run();
    assert!(o.preemptions >= 8);
    assert!((o.preemption_cost - o.preemptions as f64 * 7.5).abs() < 1e-9);
}
