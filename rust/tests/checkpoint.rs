//! Checkpoint-cost acceptance suite.
//!
//! * the headline result: with a nonzero `checkpoint_cost` the
//!   fixed-interval sweep is NON-monotone in the interval (the interior
//!   beats both endpoints), and `young_daly` — √(2·C·MTBF_gang) — meets
//!   or beats both grid endpoints on the same master streams;
//! * a conservation property: the makespan decomposes exactly into
//!   useful work + re-done (lost) work + commit overhead + recovery +
//!   stall + selection time, for every checkpoint policy — including
//!   through correlated domain outages that cut recoveries short;
//! * `recovery_total` accrues only elapsed recovery time when a domain
//!   outage interrupts a restore (the pre-fix code double-charged);
//! * `checkpoint_cost: 0` keeps the legacy accounting: zero overhead,
//!   `auto` ≡ explicit `periodic`, and the legacy text table unchanged;
//! * the batched runner stays byte-identical to fresh construction for
//!   every (stateful) checkpoint policy.

use airesim::config::{Params, TopologyLevelSpec, TopologySpec};
use airesim::model::cluster::{ReplicationRunner, Simulation};
use airesim::model::PolicySpec;
use airesim::report::{Format, RunRecord, Sink};
use airesim::scenario::{Scenario, ScenarioKind, ScenarioOutcome};
use airesim::sim::rng::Rng;
use airesim::trace::{Trace, TraceKind};

/// The scenario_checkpoint.yaml cluster: a 32-server gang whose
/// aggregate failure rate is ~2.8/day (MTBF_gang ≈ 514 min), with a
/// 30-minute commit cost — so √(2·C·MTBF) ≈ 175 min sits well inside the
/// fixed grid [30, 1920].
fn checkpoint_cluster() -> Params {
    let mut p = Params::small_test();
    p.job_size = 32;
    p.warm_standbys = 4;
    p.working_pool = 40;
    p.spare_pool = 8;
    p.job_len = 4.0 * 1440.0;
    p.random_failure_rate = 0.05 / 1440.0;
    p.systematic_failure_rate = 0.25 / 1440.0;
    p.checkpoint_cost = 30.0;
    p.max_sim_time = 1e9;
    p
}

fn with_checkpoint(name: &str) -> PolicySpec {
    let mut spec = PolicySpec::default();
    spec.set("checkpoint", name).unwrap();
    spec
}

/// Goodput of one completed single-job run: retained work per wall
/// minute.
fn goodput(out: &airesim::model::RunOutputs) -> f64 {
    assert!(out.completed);
    out.work_done / out.makespan
}

/// Mean goodput across fixed seeds — every configuration sees the same
/// master streams, the classic common-random-numbers comparison.
fn mean_goodput(p: &Params, spec: &PolicySpec, runner: &mut ReplicationRunner) -> f64 {
    let mut sum = 0.0;
    for seed in 1..=5u64 {
        sum += goodput(&runner.run(p, spec, Rng::new(seed)));
    }
    sum / 5.0
}

/// The acceptance headline: the interval knob now has a real tradeoff
/// (non-monotone sweep) and the Young/Daly interval lands at least as
/// well as both grid endpoints on the same master streams.
#[test]
fn young_daly_goodput_beats_fixed_interval_grid_endpoints() {
    let grid = [30.0, 120.0, 480.0, 1920.0];
    let mut runner = ReplicationRunner::new();

    let fixed: Vec<f64> = grid
        .iter()
        .map(|&interval| {
            let mut p = checkpoint_cluster();
            p.checkpoint_interval = interval;
            mean_goodput(&p, &with_checkpoint("periodic"), &mut runner)
        })
        .collect();
    let young = mean_goodput(&checkpoint_cluster(), &with_checkpoint("young_daly"), &mut runner);

    // Non-monotone: over-checkpointing (interval 30: ~50% of wall spent
    // writing) and under-checkpointing (interval 1920 > MTBF: most
    // cycles re-lose everything) both lose to the interior.
    let interior_best = fixed[1].max(fixed[2]);
    assert!(
        interior_best > fixed[0] && interior_best > fixed[3],
        "fixed-interval sweep must be non-monotone: {fixed:?}"
    );
    // And the analytic optimum meets or beats both endpoints.
    assert!(
        young >= fixed[0] && young >= fixed[3],
        "young_daly ({young:.4}) must beat both grid endpoints ({:.4}, {:.4})",
        fixed[0],
        fixed[3]
    );
}

// ------------------------------------------------------------------ //
// Conservation: the makespan decomposition balances
// ------------------------------------------------------------------ //

/// Decomposition check for one traced, completed, single-job run:
/// makespan = job_len + work_lost + checkpoint_overhead + recovery_total
///            + stall_time + host_selections·host_selection_time.
fn assert_decomposition(tag: &str, p: &Params, out: &airesim::model::RunOutputs, trace: &Trace) {
    assert!(out.completed, "{tag}: run must complete");
    let n_sel = trace.count(|k| matches!(k, TraceKind::HostSelection { .. }));
    let rhs = p.job_len
        + out.work_lost
        + out.checkpoint_overhead
        + out.recovery_total
        + out.stall_time
        + n_sel as f64 * p.host_selection_time;
    assert!(
        (out.makespan - rhs).abs() <= 1e-6 * out.makespan.max(1.0),
        "{tag}: makespan {} != decomposition {rhs} \
         (work_lost {}, overhead {}, recovery {}, stall {}, {n_sel} selections)",
        out.makespan,
        out.work_lost,
        out.checkpoint_overhead,
        out.recovery_total,
        out.stall_time,
    );
}

#[test]
fn makespan_decomposition_balances_across_checkpoint_policies() {
    // Moderate failure pressure: gang MTBF ~129 min on the small_test
    // cluster, so every policy sees real losses and real overhead.
    let mut base = Params::small_test();
    base.random_failure_rate = 0.1 / 1440.0;
    base.systematic_failure_rate = 0.5 / 1440.0;
    base.max_sim_time = 1e9;

    let cases: &[(&str, fn(&mut Params))] = &[
        ("continuous", |_| {}),
        ("periodic-free", |p| p.checkpoint_interval = 120.0),
        ("periodic-costed", |p| {
            p.checkpoint_interval = 120.0;
            p.checkpoint_cost = 10.0;
        }),
        ("young_daly", |p| p.checkpoint_cost = 10.0),
        ("adaptive", |p| p.checkpoint_cost = 10.0),
        ("tiered", |p| {
            p.checkpoint_interval = 60.0;
            p.checkpoint_cost = 5.0;
            p.checkpoint_tier2_interval = 240.0;
            p.checkpoint_tier2_cost = 20.0;
            p.checkpoint_tier2_restore = 45.0;
        }),
    ];
    for (name, tweak) in cases {
        let mut p = base.clone();
        tweak(&mut p);
        let policy = match *name {
            "continuous" | "periodic-free" | "periodic-costed" => {
                if p.checkpoint_interval > 0.0 { "periodic" } else { "continuous" }
            }
            other => other,
        };
        for seed in [1u64, 7, 42] {
            let (out, trace) = Simulation::from_spec(&p, &with_checkpoint(policy), Rng::new(seed))
                .unwrap()
                .with_trace()
                .run_traced();
            assert_decomposition(&format!("{name}/seed{seed}"), &p, &out, &trace);
        }
    }
}

// ------------------------------------------------------------------ //
// Recovery accounting through domain outages (satellite bugfix)
// ------------------------------------------------------------------ //

/// A 96+16-server fleet in 16-server switch domains, outage-driven only:
/// long (150-minute) restores under switch outages every ~200 minutes,
/// so recoveries are regularly cut short mid-flight.
fn outage_cluster() -> Params {
    let mut p = Params::small_test();
    p.job_size = 24;
    p.warm_standbys = 12;
    p.working_pool = 96;
    p.spare_pool = 16;
    p.job_len = 4.0 * 1440.0;
    p.random_failure_rate = 0.0;
    p.systematic_failure_rate = 0.0;
    p.systematic_fraction = 0.0;
    p.recovery_time = 150.0;
    p.auto_repair_prob = 1.0;
    p.auto_repair_fail_prob = 0.0;
    p.auto_repair_time = 60.0;
    p.max_sim_time = 1e9;
    p.topology = Some(TopologySpec {
        levels: vec![
            TopologyLevelSpec { name: "rack".into(), size: 4, outage_rate: 0.0 },
            TopologyLevelSpec { name: "switch".into(), size: 4, outage_rate: 1.0 / 1440.0 },
        ],
    });
    p
}

/// Regression for the recovery double-charge: `recovery_total` must
/// equal the *elapsed* recovery time reconstructed from the trace —
/// each `recovery_start` until the first of `recovery_done` (completed)
/// or `host_selection`/`stalled` (cut short by a domain outage). The
/// pre-fix code charged every start its full cost, over-counting every
/// interrupted restore.
#[test]
fn recovery_total_counts_only_elapsed_time_under_domain_outages() {
    let p = outage_cluster();
    let spec = PolicySpec { selection: "locality".into(), ..PolicySpec::default() };
    let mut interrupted_total = 0u64;
    for seed in 1..=10u64 {
        let (out, trace) = Simulation::from_spec(&p, &spec, Rng::new(seed))
            .unwrap()
            .with_trace()
            .run_traced();
        assert!(out.completed, "seed {seed}: run must complete");
        let mut expected = 0.0f64;
        let mut open: Option<f64> = None; // start time of the recovery in flight
        for r in &trace.records {
            match r.kind {
                TraceKind::RecoveryStart { .. } => {
                    assert!(open.is_none(), "seed {seed}: recovery started inside a recovery");
                    open = Some(r.at);
                }
                TraceKind::RecoveryDone => {
                    let start = open.take().expect("recovery_done without a start");
                    expected += r.at - start;
                }
                // A re-selection or stall while a recovery is open means a
                // domain outage broke the gang mid-restore.
                TraceKind::HostSelection { .. } | TraceKind::Stalled { .. } => {
                    if let Some(start) = open.take() {
                        expected += r.at - start;
                        interrupted_total += 1;
                    }
                }
                _ => {}
            }
        }
        assert!(open.is_none(), "seed {seed}: run completed with a recovery open");
        assert!(
            (out.recovery_total - expected).abs() <= 1e-6 * expected.max(1.0),
            "seed {seed}: recovery_total {} != elapsed recovery time {expected}",
            out.recovery_total
        );
    }
    assert!(
        interrupted_total > 0,
        "the scenario must actually cut recoveries short to regress the double-charge"
    );
}

/// The decomposition also balances when domain outages interrupt
/// recoveries and selections mid-flight (selection time pinned to 0 so
/// partially-elapsed selections cannot skew the selection term).
#[test]
fn makespan_decomposition_balances_through_domain_outages() {
    let mut p = outage_cluster();
    p.host_selection_time = 0.0;
    p.checkpoint_interval = 120.0;
    p.checkpoint_cost = 10.0;
    for policy in ["continuous", "periodic", "young_daly"] {
        // young_daly's gang rate counts the domain-outage exposure, so
        // it self-optimizes here even with the per-server clocks off.
        let mut spec = with_checkpoint(policy);
        spec.set("selection", "locality").unwrap();
        for seed in [3u64, 11] {
            let (out, trace) = Simulation::from_spec(&p, &spec, Rng::new(seed))
                .unwrap()
                .with_trace()
                .run_traced();
            assert_decomposition(&format!("outage/{policy}/seed{seed}"), &p, &out, &trace);
        }
    }
}

// ------------------------------------------------------------------ //
// checkpoint_cost = 0: the legacy model, byte for byte
// ------------------------------------------------------------------ //

#[test]
fn zero_cost_keeps_legacy_accounting() {
    let mut p = Params::small_test();
    p.checkpoint_interval = 120.0;
    // `auto` and an explicit `periodic` are one code path with cost 0.
    let auto_out =
        Simulation::from_spec(&p, &PolicySpec::default(), Rng::new(9)).unwrap().run();
    let explicit =
        Simulation::from_spec(&p, &with_checkpoint("periodic"), Rng::new(9)).unwrap().run();
    assert_eq!(auto_out, explicit);
    assert_eq!(auto_out.checkpoint_overhead, 0.0, "free commits cost nothing");
    assert!(auto_out.checkpoints_committed > 0, "commits are still counted");
    assert!(auto_out.work_lost > 0.0, "interval granularity still loses work");

    // The paper default (continuous) moves none of the new accounting.
    let base = Params::small_test();
    let c = Simulation::new(&base, 42).run();
    assert!(c.completed);
    assert_eq!(c.checkpoints_committed, 0);
    assert_eq!(c.checkpoint_overhead, 0.0);
    assert_eq!(c.work_lost, 0.0);
    assert!((c.work_done - base.job_len).abs() < 1e-6);
}

/// The pinned legacy text table must not grow the new checkpoint
/// metrics — they live in the machine sinks only (same contract the
/// topology metrics follow).
#[test]
fn costed_runs_render_the_legacy_text_block() {
    let mut p = checkpoint_cluster();
    p.checkpoint_interval = 120.0;
    let outputs =
        Simulation::from_spec(&p, &with_checkpoint("periodic"), Rng::new(7)).unwrap().run();
    assert!(outputs.checkpoint_overhead > 0.0, "the cost model must engage");
    let rec = RunRecord {
        seed: 7,
        params: p,
        policies: with_checkpoint("periodic"),
        outputs,
        trace: Trace::default(),
    };
    let text = Format::Text.sink().run(&rec);
    assert!(!text.contains("checkpoint"), "checkpoint metrics stay out of the legacy table");
    assert!(!text.contains("goodput"), "goodput stays out of the legacy table");
    let json = Format::Json.sink().run(&rec);
    for m in ["checkpoints_committed", "checkpoint_overhead", "goodput_fraction"] {
        assert!(json.contains(&format!("\"{m}\"")), "json missing {m}");
    }
}

// ------------------------------------------------------------------ //
// Runner reuse and plumbing
// ------------------------------------------------------------------ //

/// The stateful policies (per-job intervals, committed points, tier
/// bookkeeping, adaptive windows) must reset between batched
/// replications — reuse stays byte-identical to fresh construction.
#[test]
fn batched_runner_matches_fresh_for_checkpoint_policies() {
    let mut p = checkpoint_cluster();
    p.checkpoint_interval = 120.0;
    p.checkpoint_tier2_interval = 480.0;
    p.checkpoint_tier2_cost = 60.0;
    p.checkpoint_tier2_restore = 45.0;
    for name in ["periodic", "young_daly", "adaptive", "tiered"] {
        let spec = with_checkpoint(name);
        let mut runner = ReplicationRunner::new();
        for seed in [5u64, 21] {
            let batched = runner.run(&p, &spec, Rng::new(seed));
            let fresh = Simulation::from_spec(&p, &spec, Rng::new(seed)).unwrap().run();
            assert_eq!(batched, fresh, "{name} seed {seed}: runner reuse diverged");
        }
    }
}

#[test]
fn shipped_checkpoint_scenario_config_runs() {
    let text = std::fs::read_to_string("configs/scenario_checkpoint.yaml").unwrap();
    let mut sc = Scenario::from_yaml(&text).unwrap();
    match &mut sc.kind {
        ScenarioKind::Sweep(sweep) => {
            assert!(sweep.crn, "the comparison must run on common random numbers");
            assert_eq!(sweep.points.len(), 8, "2 policies x 4 intervals");
            sweep.replications = 2; // scaled-down execution, same mechanics
        }
        _ => panic!("scenario_checkpoint.yaml must be a sweep"),
    }
    match sc.run().unwrap() {
        ScenarioOutcome::Sweep(result) => {
            for pr in &result.points {
                assert_eq!(pr.summary("goodput_fraction").unwrap().n, 2);
                assert_eq!(pr.summary("completed").unwrap().mean, 1.0, "{}", pr.point.label());
            }
            // young_daly ignores the interval axis: its four rows are
            // identical by construction (same config, same CRN streams)
            // — a built-in determinism check the config's comment
            // documents.
            let young: Vec<f64> = result
                .points
                .iter()
                .filter(|pr| pr.point.label().contains("policies.checkpoint=young_daly"))
                .map(|pr| pr.summary("makespan").unwrap().mean)
                .collect();
            assert_eq!(young.len(), 4);
            for m in &young[1..] {
                assert_eq!(*m, young[0], "young_daly rows must be interval-independent");
            }
        }
        _ => panic!("expected Sweep outcome"),
    }
}

/// Satellite bugfix: an explicit `checkpoint: periodic` with no interval
/// configured fails at scenario parse time, naming the knob.
#[test]
fn scenario_rejects_explicit_periodic_without_interval() {
    let text = "scenario: single\npolicies:\n  checkpoint: periodic\n";
    let err = Scenario::from_yaml(text).unwrap_err();
    assert!(err.contains("checkpoint_interval"), "{err}");
    // Policy-axis sweeps hit the same validation before any worker runs.
    let text = "scenario: sweep\nreplications: 1\n\
                sweep:\n  kind: one_way\n  x: { name: policies.checkpoint, values: [periodic] }\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let err = sc.run().unwrap_err();
    assert!(err.contains("checkpoint_interval"), "{err}");
}

/// A sweep may supply the very knob a policy needs: `checkpoint:
/// periodic` with the interval coming only from the sweep axis is valid
/// at every run point and must not be rejected against the bare base
/// params.
#[test]
fn sweeping_the_knob_a_policy_needs_is_allowed() {
    let text = "scenario: sweep\nreplications: 2\nseed: 1\n\
        params:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n\
        policies:\n  checkpoint: periodic\n\
        sweep:\n  kind: one_way\n  x: { name: checkpoint_interval, values: [60, 120] }\n";
    let sc = Scenario::from_yaml(text).unwrap();
    match sc.run().unwrap() {
        ScenarioOutcome::Sweep(result) => {
            assert_eq!(result.points.len(), 2);
            for pr in &result.points {
                assert!(pr.summary("work_lost").unwrap().mean > 0.0, "{}", pr.point.label());
            }
        }
        _ => panic!("expected Sweep outcome"),
    }
    // A sweep whose points never supply the interval still fails — at
    // validate time, naming the knob with the point's label.
    let text = "scenario: sweep\nreplications: 1\npolicies:\n  checkpoint: periodic\n\
        sweep:\n  kind: one_way\n  x: { name: recovery_time, values: [10] }\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let err = sc.run().unwrap_err();
    assert!(err.contains("checkpoint_interval"), "{err}");
}

/// Horizon-cut runs count the in-flight burst: a failure-free job that
/// ran the entire horizon reports the horizon's work, not zero (the
/// `work_done`/`goodput_fraction` accounting must not depend on the job
/// reaching a pause).
#[test]
fn horizon_cut_counts_in_flight_work() {
    let mut p = Params::small_test();
    p.random_failure_rate = 0.0;
    p.systematic_failure_rate = 0.0;
    p.systematic_fraction = 0.0;
    p.job_len = 2000.0;
    p.max_sim_time = 1000.0;
    let out = Simulation::new(&p, 1).run();
    assert!(!out.completed);
    // One host selection (3 min), then one burst to the horizon.
    let expect = 1000.0 - p.host_selection_time;
    assert!(
        (out.work_done - expect).abs() < 1e-6,
        "work_done {} != in-flight work {expect}",
        out.work_done
    );

    // With a commit cost the horizon accounting still inverts the wall
    // clock into work + overhead exactly.
    p.checkpoint_interval = 100.0;
    p.checkpoint_cost = 10.0;
    let out = Simulation::from_spec(&p, &with_checkpoint("periodic"), Rng::new(1))
        .unwrap()
        .run();
    assert!(!out.completed);
    assert!(out.checkpoints_committed >= 8, "{}", out.checkpoints_committed);
    let wall = 1000.0 - p.host_selection_time;
    assert!(
        (out.work_done + out.checkpoint_overhead - wall).abs() < 1e-6,
        "work {} + overhead {} != wall {wall}",
        out.work_done,
        out.checkpoint_overhead
    );
}
