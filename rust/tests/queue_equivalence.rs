//! Cross-queue equivalence: the calendar queue must be observationally
//! identical to the binary heap — not statistically, *byte-for-byte*.
//! The engine's delivery contract is earliest-`at` first with FIFO
//! tie-breaks by schedule order, and both backends implement it exactly,
//! so every pop (time, payload), every `pending()` count, and every full
//! simulation output must agree.
//!
//! Layers of evidence:
//! * lockstep random-workload drive (property harness, hostile `dt` mix:
//!   exact ties, sub-bucket-width clusters, far-future outliers that land
//!   in the calendar's overflow list);
//! * explicit FIFO-tie and outlier regressions;
//! * `reset()`-reuse round two (the calendar keeps its learned geometry);
//! * whole-simulation output equality over the config zoo.

use airesim::config::{DistKind, Params};
use airesim::model::cluster::Simulation;
use airesim::sim::engine::{Engine, QueueKind};
use airesim::testkit::{check, Gen};

/// Drive both backends with an identical op sequence; assert every
/// observable agrees at every step. Payload = schedule index, so payload
/// equality proves FIFO tie-breaking matches too.
fn lockstep(g: &mut Gen, rounds: usize) {
    let mut cal: Engine<u64> = Engine::with_queue(QueueKind::Calendar, 16);
    let mut heap: Engine<u64> = Engine::with_queue(QueueKind::Heap, 16);
    let mut tag = 0u64;
    for _ in 0..rounds {
        // A burst of schedules with a hostile delay mix.
        for _ in 0..g.usize_in(0, 12) {
            let dt = match g.usize_in(0, 9) {
                // Exact ties, sub-bucket-width clusters, far-future
                // outliers, and typical delays, in that order.
                0 => 0.0,
                1 => g.f64_in(0.0, 1e-6),
                2 => g.f64_in(1e6, 1e9),
                _ => g.f64_in(0.0, 1e3),
            };
            cal.schedule_in(dt, tag);
            heap.schedule_in(dt, tag);
            tag += 1;
        }
        // A burst of pops, compared element-wise.
        for _ in 0..g.usize_in(0, 12) {
            let a = cal.pop();
            let b = heap.pop();
            assert_eq!(a, b, "pop diverged (after {tag} schedules)");
            if a.is_none() {
                break;
            }
        }
        assert_eq!(cal.pending(), heap.pending());
        assert_eq!(cal.now(), heap.now());
        assert_eq!(cal.peek_time(), heap.peek_time());
    }
    // Full drain: remaining order must also agree.
    loop {
        let a = cal.pop();
        let b = heap.pop();
        assert_eq!(a, b, "drain diverged");
        if a.is_none() {
            break;
        }
    }
    assert_eq!(cal.scheduled(), heap.scheduled());
    assert_eq!(cal.delivered(), heap.delivered());
}

#[test]
fn calendar_matches_heap_under_random_workloads() {
    check("calendar ≡ heap lockstep", 40, |g| {
        let rounds = g.usize_in(10, 120);
        lockstep(g, rounds);
    });
}

#[test]
fn fifo_ties_deliver_in_schedule_order_on_both_queues() {
    for kind in [QueueKind::Calendar, QueueKind::Heap] {
        let mut e: Engine<u64> = Engine::with_queue(kind, 4);
        // Interleave two tie groups with a distinct time between them.
        for i in 0..8 {
            e.schedule_at(5.0, i);
        }
        e.schedule_at(2.0, 100);
        for i in 8..16 {
            e.schedule_at(5.0, i);
        }
        assert_eq!(e.pop(), Some((2.0, 100)));
        for i in 0..16 {
            assert_eq!(e.pop(), Some((5.0, i)), "{kind:?} broke FIFO ties");
        }
        assert_eq!(e.pop(), None);
    }
}

#[test]
fn far_future_outliers_come_back_in_order() {
    let mut cal: Engine<u32> = Engine::with_queue(QueueKind::Calendar, 8);
    let mut heap: Engine<u32> = Engine::with_queue(QueueKind::Heap, 8);
    for e in [&mut cal as &mut Engine<u32>, &mut heap] {
        e.schedule_at(1e9, 3); // lands in the calendar overflow list
        e.schedule_at(1.0, 1);
        e.schedule_at(5e8, 2);
        e.schedule_at(2e9, 4);
    }
    for _ in 0..5 {
        assert_eq!(cal.pop(), heap.pop());
    }
}

#[test]
fn reset_reuse_stays_equivalent() {
    // Round one teaches the calendar a bucket geometry; round two (after
    // reset) must still match the heap exactly with a different workload.
    let mut g = Gen::new(0xCA1E_4DA2);
    let mut cal: Engine<u64> = Engine::with_queue(QueueKind::Calendar, 16);
    let mut heap: Engine<u64> = Engine::with_queue(QueueKind::Heap, 16);
    for round in 0..3 {
        let scale = [1e3, 1e7, 1.0][round]; // shift the time scale each round
        for i in 0..500u64 {
            let at = g.f64_in(0.0, scale);
            cal.schedule_at(at, i);
            heap.schedule_at(at, i);
        }
        loop {
            let a = cal.pop();
            assert_eq!(a, heap.pop(), "round {round} diverged");
            if a.is_none() {
                break;
            }
        }
        cal.reset(16);
        heap.reset(16);
        assert_eq!(cal.pending(), 0);
        assert_eq!(cal.now(), 0.0);
    }
}

/// Whole-simulation equality: same seed, same config, both queue kinds →
/// byte-identical `RunOutputs`. This is the end-to-end form of the
/// "default outputs stay byte-identical" acceptance bar.
#[test]
fn full_simulation_outputs_identical_across_queues() {
    let mut zoo = vec![Params::small_test()];

    let mut multi = Params::small_test();
    multi.num_jobs = 2;
    multi.job_size = 24;
    multi.warm_standbys = 2;
    multi.working_pool = 56;
    multi.spare_pool = 8;
    zoo.push(multi);

    let mut churn = Params::small_test();
    churn.bad_regen_interval = 300.0;
    churn.bad_regen_fraction = 0.05;
    zoo.push(churn);

    let mut weibull = Params::small_test();
    weibull.failure_dist = DistKind::Weibull { shape: 1.5 };
    weibull.max_sim_time = 1e9;
    zoo.push(weibull);

    for (i, p) in zoo.iter().enumerate() {
        for seed in [1u64, 42, 4242] {
            let a = Simulation::new(p, seed).with_queue(QueueKind::Calendar).run();
            let b = Simulation::new(p, seed).with_queue(QueueKind::Heap).run();
            assert_eq!(a, b, "config {i} seed {seed}: queues diverged");
        }
    }
}
