//! Figure-1 flowchart branch coverage: scripted failure injections walk
//! the scheduler through every decision branch (standby swap, host
//! selection, spare-pool preemption, stall) and the trace asserts which
//! branch was taken.

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::model::events::FailureKind;
use airesim::trace::inject::{Injection, InjectionPlan};
use airesim::trace::TraceKind;

/// A failure-free base config so only injected failures drive the run.
fn quiet_params() -> Params {
    let mut p = Params::small_test();
    p.random_failure_rate = 0.0;
    p.systematic_failure_rate = 0.0;
    p.systematic_fraction = 0.0;
    // Long repairs: failed servers do not come back within the job.
    p.auto_repair_time = 1e7;
    p.manual_repair_time = 1e7;
    p.diagnosis_prob = 1.0;
    p.diagnosis_uncertainty = 0.0;
    p
}

fn inject_at(times: &[f64]) -> InjectionPlan {
    InjectionPlan::new(
        times
            .iter()
            .map(|&at| Injection::new(at, 0, FailureKind::Random))
            .collect(),
    )
}

#[test]
fn failure_free_run_is_exact() {
    let p = quiet_params();
    let out = Simulation::new(&p, 1).run();
    assert!(out.completed);
    assert_eq!(out.failures_total, 0);
    // makespan = initial host selection + job length.
    assert!((out.makespan - (p.host_selection_time + p.job_len)).abs() < 1e-6);
    assert_eq!(out.standby_swaps, 0);
    assert_eq!(out.host_selections, 0);
    assert_eq!(out.preemptions, 0);
}

#[test]
fn branch_standby_swap() {
    // One failure with standbys available: swap, pay recovery only.
    let p = quiet_params(); // 4 warm standbys
    let (out, trace) = Simulation::new(&p, 1)
        .with_trace()
        .with_injections(inject_at(&[100.0]))
        .run_traced();
    assert!(out.completed);
    assert_eq!(out.failures_total, 1);
    assert_eq!(out.standby_swaps, 1);
    assert_eq!(out.host_selections, 0);
    assert_eq!(trace.count(|k| matches!(k, TraceKind::StandbySwap { .. })), 1);
    // makespan = initial selection + job + one recovery.
    let want = p.host_selection_time + p.job_len + p.recovery_time;
    assert!(
        (out.makespan - want).abs() < 1e-6,
        "makespan {} want {want}",
        out.makespan
    );
}

#[test]
fn branch_host_selection_from_working_pool() {
    // Exhaust the 4 standbys, then the 5th failure triggers host selection
    // from working-pool idle (72 - 68 = 4 idle available).
    let p = quiet_params();
    let (out, trace) = Simulation::new(&p, 1)
        .with_trace()
        .with_injections(inject_at(&[100.0, 200.0, 300.0, 400.0, 500.0]))
        .run_traced();
    assert!(out.completed);
    assert_eq!(out.failures_total, 5);
    assert_eq!(out.standby_swaps, 4);
    assert_eq!(out.host_selections, 1);
    // The re-allotment tops standbys back up to job_size + warm: 63
    // surviving + 4 idle = 67 < 68, so exactly one spare is preempted.
    assert_eq!(out.preemptions, 1);
    assert!(trace.count(|k| matches!(k, TraceKind::HostSelection { .. })) >= 1);
    // makespan = initial sel + job + 5 recoveries + 1 host selection
    // (the preempted spare arrives during recovery; no extra delay).
    let want = p.host_selection_time + p.job_len + 5.0 * p.recovery_time
        + p.host_selection_time;
    assert!(
        (out.makespan - want).abs() < 1e-6,
        "makespan {} want {want}",
        out.makespan
    );
}

#[test]
fn branch_preemption_from_spare_pool() {
    // 9 failures: 4 standby swaps, then selections drain the 4 idle
    // working-pool servers; the next shortfall preempts from spares.
    let p = quiet_params();
    let times: Vec<f64> = (1..=9).map(|i| 130.0 * i as f64).collect();
    let (out, trace) = Simulation::new(&p, 1)
        .with_trace()
        .with_injections(inject_at(&times))
        .run_traced();
    assert!(out.completed);
    assert_eq!(out.failures_total, 9);
    assert!(out.preemptions > 0, "expected spare-pool preemptions");
    assert!(trace.count(|k| matches!(k, TraceKind::Preempted { .. })) > 0);
    assert!(trace.count(|k| matches!(k, TraceKind::PreemptArrived { .. })) > 0);
}

#[test]
fn branch_stall_when_everything_exhausted() {
    // Tiny pools: one failure beyond capacity stalls the job until the
    // (eventually finishing) repair returns the server.
    let mut p = quiet_params();
    p.working_pool = 64; // no idle slack
    p.spare_pool = 0;
    p.warm_standbys = 0;
    p.auto_repair_time = 500.0; // repair returns within the horizon
    p.auto_repair_prob = 1.0;
    p.auto_repair_fail_prob = 0.0;
    let (out, trace) = Simulation::new(&p, 3)
        .with_trace()
        .with_injections(inject_at(&[100.0]))
        .run_traced();
    assert!(out.completed, "job should finish after the repair returns");
    assert!(out.stall_time > 0.0, "expected a stall");
    assert!(trace.count(|k| matches!(k, TraceKind::Stalled { .. })) >= 1);
    assert!(trace.count(|k| matches!(k, TraceKind::Unstalled { .. })) >= 1);
}

#[test]
fn undiagnosed_failure_restarts_in_place() {
    let mut p = quiet_params();
    p.diagnosis_prob = 0.0; // never identify a culprit
    let (out, trace) = Simulation::new(&p, 1)
        .with_trace()
        .with_injections(inject_at(&[100.0, 200.0]))
        .run_traced();
    assert!(out.completed);
    assert_eq!(out.failures_total, 2);
    assert_eq!(out.undiagnosed, 2);
    assert_eq!(out.standby_swaps, 0, "nobody leaves the gang");
    assert_eq!(out.repairs_auto + out.repairs_manual, 0);
    assert_eq!(trace.count(|k| matches!(k, TraceKind::RepairStart { .. })), 0);
    let want = p.host_selection_time + p.job_len + 2.0 * p.recovery_time;
    assert!((out.makespan - want).abs() < 1e-6);
}

#[test]
fn wrong_diagnosis_blames_innocent_peer() {
    let mut p = quiet_params();
    p.diagnosis_prob = 1.0;
    p.diagnosis_uncertainty = 1.0; // always wrong
    let (out, _) = Simulation::new(&p, 1)
        .with_trace()
        .with_injections(inject_at(&[100.0]))
        .run_traced();
    assert!(out.completed);
    assert_eq!(out.wrong_diagnoses, 1);
    // A server still left the gang (the wrong one) and was replaced.
    assert_eq!(out.standby_swaps, 1);
}

#[test]
fn repaired_server_returns_to_its_job() {
    // Fast, always-successful auto repair: the failed server returns to
    // the job's standby set (assigned_job routing) before the next
    // failure, so standbys never run out.
    let mut p = quiet_params();
    p.auto_repair_time = 10.0;
    p.auto_repair_prob = 1.0;
    p.auto_repair_fail_prob = 0.0;
    let times: Vec<f64> = (1..=10).map(|i| 100.0 * i as f64).collect();
    let (out, trace) = Simulation::new(&p, 1)
        .with_trace()
        .with_injections(inject_at(&times))
        .run_traced();
    assert!(out.completed);
    assert_eq!(out.failures_total, 10);
    assert_eq!(out.host_selections, 0, "returns should keep standbys stocked");
    assert_eq!(out.repairs_auto, 10);
    assert!(trace.count(|k| matches!(k, TraceKind::RepairDone { .. })) == 10);
}

#[test]
fn retirement_threshold_removes_server() {
    let mut p = quiet_params();
    p.retirement_threshold = 2;
    p.retirement_window = 1e9;
    p.auto_repair_time = 10.0; // comes back fast, fails again
    p.auto_repair_prob = 1.0;
    p.auto_repair_fail_prob = 1.0; // never actually fixed
    // victim_index 0 targets the same (returning) server each time only if
    // it rotates back to position 0; instead target whatever is active.
    let plan = InjectionPlan::new(vec![
        Injection::new(100.0, 3, FailureKind::Systematic),
        Injection::new(200.0, 3, FailureKind::Systematic),
        Injection::new(300.0, 3, FailureKind::Systematic),
    ]);
    let (out, trace) = Simulation::new(&p, 1)
        .with_trace()
        .with_injections(plan)
        .run_traced();
    assert!(out.completed);
    // Some victim accumulated 2 failures within the (infinite) window only
    // if the same slot is hit twice after return; at minimum the
    // retirement machinery must fire when any server reaches 2 failures.
    let retired = trace.count(|k| matches!(k, TraceKind::Retired { .. }));
    assert_eq!(out.retirements as usize, retired);
}
