//! Acceptance suite for `multi:` studies (the composable-scenario API):
//!
//! * the shipped study config parses, runs, and renders in all four
//!   formats (one combined comparison record per invocation);
//! * a child's per-child outputs are byte-equal to running that child
//!   standalone on the same seed (label-keyed streams);
//! * study results are byte-equal across worker thread counts (the
//!   shared-pool analogue of the sweep equality test);
//! * YAML error paths are clean build errors naming the offender.

use airesim::model::PolicySpec;
use airesim::report::json::Json;
use airesim::report::{Format, Sink};
use airesim::scenario::study::{run_study, Study, StudyChild};
use airesim::scenario::{Scenario, ScenarioKind, ScenarioOutcome};
use airesim::stats::metrics;
use airesim::testkit::parse_json;

const SMALL: &str = "params:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n";

fn obj_get<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

fn four_child_study_yaml() -> String {
    format!(
        "scenario: multi\ntitle: study test\nseed: 9\nreplications: 3\nbaseline: slow\n{SMALL}\
         children:\n\
         \x20 - label: slow\n    params: {{ recovery_time: 60 }}\n\
         \x20 - label: fast\n    params: {{ recovery_time: 5 }}\n\
         \x20 - label: packed\n    policies: {{ selection: locality }}\n\
         \x20 - label: aged\n    policies: {{ repair: sla_aged }}\n    params: {{ repair_sla_minutes: 120 }}\n"
    )
}

fn run_four_child(threads: usize) -> airesim::report::StudyRecord {
    let mut sc = Scenario::from_yaml(&four_child_study_yaml()).unwrap();
    sc.threads = threads;
    match sc.run().unwrap() {
        ScenarioOutcome::Study(rec) => rec,
        _ => panic!("expected Study outcome"),
    }
}

#[test]
fn shipped_study_config_parses_and_runs() {
    let text = std::fs::read_to_string("configs/scenario_study.yaml").unwrap();
    let sc = Scenario::from_yaml(&text).unwrap();
    let ScenarioKind::Multi(study) = &sc.kind else { panic!("expected multi kind") };
    assert_eq!(study.children.len(), 4);
    assert!(study.crn);
    assert_eq!(study.baseline, Some(0));
    let ScenarioOutcome::Study(rec) = sc.run().unwrap() else { panic!() };
    assert_eq!(rec.baseline_label(), Some("locality_periodic"));
    for c in &rec.children {
        assert_eq!(c.summary("makespan").unwrap().n, 8, "{}", c.label);
    }
    // The interesting joint signal exists: young_daly children commit
    // checkpoints (the periodic children do too, on the fixed grid).
    for c in &rec.children {
        assert!(
            c.summary("checkpoints_committed").unwrap().mean > 0.0,
            "{} committed nothing",
            c.label
        );
    }
}

#[test]
fn study_renders_in_all_four_formats() {
    let sc = Scenario::from_yaml(&four_child_study_yaml()).unwrap();
    let outcome = sc.run().unwrap();
    let record = sc.record(&outcome);

    // Text: roster + one comparison block per metric, baseline marked.
    let text = Format::Text.sink().scenario(&record);
    assert!(text.contains("== scenario: study test [multi] =="), "{text}");
    assert!(text.contains("study: 4 children x 3 replications"), "{text}");
    assert!(text.contains("baseline slow"), "{text}");
    for label in ["slow", "fast", "packed", "aged"] {
        assert!(text.contains(label), "text misses child {label}: {text}");
    }
    assert!(text.contains("Δ%"), "{text}");

    // JSON: one document; children carry every registry metric summary,
    // the comparison carries every registry metric row.
    let doc = parse_json(Format::Json.sink().scenario(&record).trim_end()).unwrap();
    assert_eq!(obj_get(&doc, "scenario"), Some(&Json::str("multi")));
    let result = obj_get(&doc, "result").unwrap();
    assert_eq!(obj_get(result, "baseline"), Some(&Json::str("slow")));
    let Some(Json::Arr(children)) = obj_get(result, "children") else { panic!() };
    assert_eq!(children.len(), 4);
    for child in children {
        let m = obj_get(child, "metrics").unwrap();
        for name in metrics::names() {
            assert!(obj_get(m, name).is_some(), "child json missing {name}");
        }
        assert!(obj_get(child, "policies").is_some());
    }
    let Some(Json::Arr(rows)) = obj_get(result, "comparison") else { panic!() };
    assert_eq!(rows.len(), metrics::REGISTRY.len());
    let Some(Json::Arr(first)) = obj_get(&rows[0], "children") else { panic!() };
    assert_eq!(first.len(), 4);
    assert!(obj_get(&first[0], "delta").is_none(), "baseline row carries no delta");
    assert!(obj_get(&first[1], "delta").is_some(), "non-baseline rows carry deltas");

    // CSV: long form, one row per (metric, child).
    let csv = Format::Csv.sink().scenario(&record);
    let mut lines = csv.lines();
    assert_eq!(
        lines.next().unwrap(),
        "metric,unit,child,n,mean,std,ci95,delta,delta_pct,delta_ci,significant"
    );
    assert_eq!(csv.lines().count(), 1 + metrics::REGISTRY.len() * 4);
    assert!(csv.contains("\nmakespan,min,slow,3,"), "{csv}");

    // NDJSON: meta + 4 child lines + one comparison line per metric,
    // every line independently parseable.
    let nd = Format::Ndjson.sink().scenario(&record);
    let mut counts = std::collections::BTreeMap::new();
    for line in nd.trim_end().lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("bad line `{line}`: {e}"));
        let Some(Json::Str(t)) = obj_get(&doc, "type") else { panic!("untyped line") };
        *counts.entry(t.clone()).or_insert(0usize) += 1;
    }
    assert_eq!(counts.get("scenario"), Some(&1));
    assert_eq!(counts.get("child"), Some(&4));
    assert_eq!(counts.get("comparison"), Some(&metrics::REGISTRY.len()));
}

/// The acceptance property: a child's outputs inside a study are
/// byte-equal to running that child standalone (a one-child study on the
/// same seed) — label-keyed streams make siblings invisible.
#[test]
fn study_children_byte_equal_standalone_runs() {
    let full = run_four_child(2);
    for child in ["slow", "fast", "packed", "aged"] {
        let idx = full.children.iter().position(|c| c.label == child).unwrap();
        let solo_spec = Study {
            children: vec![StudyChild {
                label: child.into(),
                overrides: full.children[idx].overrides.clone(),
            }],
            baseline: None,
            replications: 3,
            crn: false,
            show_ci: false,
        };
        let sc = Scenario::from_yaml(&four_child_study_yaml()).unwrap();
        let solo = run_study(&sc.params, &PolicySpec::default(), &solo_spec, 9, 1).unwrap();
        for m in metrics::REGISTRY {
            assert_eq!(
                full.children[idx].summary(m.name),
                solo.children[0].summary(m.name),
                "child {child} metric {} diverged from its standalone run",
                m.name
            );
        }
    }
}

/// The cross-thread-count equality test, extended to studies: the shared
/// work queue interleaves children arbitrarily across workers, but every
/// collected metric must agree bit-for-bit.
#[test]
fn study_outputs_identical_across_thread_counts() {
    let a = run_four_child(1);
    let b = run_four_child(4);
    for (ca, cb) in a.children.iter().zip(&b.children) {
        assert_eq!(ca.label, cb.label);
        for m in metrics::REGISTRY {
            assert_eq!(
                ca.summary(m.name),
                cb.summary(m.name),
                "child {} metric {} diverged across thread counts",
                ca.label,
                m.name
            );
        }
    }
}

/// CRN puts every child on the same master streams: two children with
/// identical overrides become bit-identical, and the whole study stays
/// thread-count independent.
#[test]
fn crn_study_twins_are_bit_identical() {
    let text = format!(
        "scenario: multi\nseed: 5\nreplications: 3\ncrn: true\n{SMALL}\
         children:\n  - label: a\n  - label: twin_of_a\n  - label: fast\n    params: {{ recovery_time: 5 }}\n"
    );
    let sc = Scenario::from_yaml(&text).unwrap();
    let ScenarioOutcome::Study(rec) = sc.run().unwrap() else { panic!() };
    for m in metrics::REGISTRY {
        assert_eq!(
            rec.children[0].summary(m.name),
            rec.children[1].summary(m.name),
            "CRN twins diverged on {}",
            m.name
        );
    }
}

/// Every `multi:` YAML error path is a clean parse-time error naming the
/// offender (through the full Scenario::from_yaml entry point).
#[test]
fn study_yaml_error_paths() {
    let parse = |body: &str| {
        Scenario::from_yaml(&format!("scenario: multi\n{SMALL}{body}")).unwrap_err()
    };
    // Empty child list.
    let err = parse("children: []\n");
    assert!(err.contains("at least one child"), "{err}");
    // Duplicate child labels.
    let err = parse("children:\n  - label: x\n  - label: x\n");
    assert!(err.contains("duplicate") && err.contains("`x`"), "{err}");
    // Unknown baseline label (the error lists the real children).
    let err = parse("baseline: bogus\nchildren:\n  - label: x\n  - label: y\n");
    assert!(err.contains("bogus") && err.contains('y'), "{err}");
    // Child overriding a nonexistent param.
    let err = parse("children:\n  - label: x\n    params: { not_a_knob: 1 }\n");
    assert!(err.contains("`x`") && err.contains("not_a_knob"), "{err}");
    // Child policy that cannot build against its resolved params.
    let err = parse("children:\n  - label: x\n    policies: { checkpoint: young_daly }\n");
    assert!(err.contains("`x`") && err.contains("checkpoint_cost"), "{err}");
    // A label-less child.
    let err = parse("children:\n  - params: { recovery_time: 5 }\n");
    assert!(err.contains("label"), "{err}");
    // A misspelled override section (would silently run the base config).
    let err = parse("children:\n  - label: x\n    parms: { recovery_time: 5 }\n");
    assert!(err.contains("`x`") && err.contains("parms"), "{err}");
}

/// Free-form child labels survive the CSV sink: a label containing a
/// comma is quoted, not split across columns.
#[test]
fn csv_quotes_free_form_child_labels() {
    let text = format!(
        "scenario: multi\nseed: 2\nreplications: 1\n{SMALL}\
         children:\n  - label: \"locality, tuned\"\n"
    );
    let sc = Scenario::from_yaml(&text).unwrap();
    let outcome = sc.run().unwrap();
    let csv = Format::Csv.sink().scenario(&sc.record(&outcome));
    let row = csv.lines().nth(1).unwrap();
    assert!(row.starts_with("makespan,min,\"locality, tuned\",1,"), "{row}");
}

/// Base `policies:` apply to every child; child `policies:` override per
/// axis (visible in the per-child resolved spec of the record).
#[test]
fn base_policies_compose_with_child_overrides() {
    let text = format!(
        "scenario: multi\nseed: 2\nreplications: 1\n{SMALL}\
         policies:\n  repair: job_first\n\
         children:\n  - label: inherit\n  - label: override\n    policies: {{ repair: lifo }}\n"
    );
    let sc = Scenario::from_yaml(&text).unwrap();
    let ScenarioOutcome::Study(rec) = sc.run().unwrap() else { panic!() };
    assert_eq!(rec.children[0].policies.repair, "job_first");
    assert_eq!(rec.children[1].policies.repair, "lifo");
    assert_eq!(rec.children[1].policies.selection, "first_fit", "other axes inherited");
}
