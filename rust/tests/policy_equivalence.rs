//! Refactor-equivalence suite for the policy-subsystem decomposition.
//!
//! What each layer of evidence actually proves:
//! * entry-point agreement — `new`/`with_rng`/`from_spec(default)` are
//!   one path; the test below pins that the *API surface* stays unified;
//! * batched-runner equivalence — reuse really is compared against an
//!   independent code path (fresh construction), byte-for-byte;
//! * the golden snapshot pins fixed-seed behavior *across commits* —
//!   but only once the blessed file is committed (see its note);
//! * thread-count equality pins scheduling independence.

use airesim::config::{DistKind, Params};
use airesim::model::cluster::{ReplicationRunner, Simulation};
use airesim::model::{PolicySpec, RunOutputs};
use airesim::sim::rng::Rng;
use airesim::sweep::{run_sweep, Sweep};

/// A spread of configurations exercising every subsystem: baseline,
/// multi-job contention, regeneration + retirement, finite repair
/// capacity + checkpoint loss, and a non-exponential clock family.
fn config_zoo() -> Vec<Params> {
    let base = Params::small_test();

    let mut multi = Params::small_test();
    multi.num_jobs = 2;
    multi.job_size = 24;
    multi.warm_standbys = 2;
    multi.working_pool = 56;
    multi.spare_pool = 8;

    let mut churn = Params::small_test();
    churn.bad_regen_interval = 300.0;
    churn.bad_regen_fraction = 0.05;
    churn.retirement_threshold = 3;
    churn.retirement_window = 1e5;

    let mut constrained = Params::small_test();
    constrained.auto_repair_capacity = 2;
    constrained.manual_repair_capacity = 1;
    constrained.checkpoint_interval = 120.0;

    let mut weibull = Params::small_test();
    weibull.failure_dist = DistKind::Weibull { shape: 1.5 };
    weibull.max_sim_time = 1e9;

    vec![base, multi, churn, constrained, weibull]
}

/// All three constructors are one code path today; this pins that they
/// *stay* unified (a future divergence — e.g. `new` gaining different
/// defaults than `from_spec(default)` — is an API regression).
#[test]
fn entry_points_agree_for_default_policies() {
    for (i, p) in config_zoo().iter().enumerate() {
        for seed in [1u64, 42, 1234] {
            let via_new = Simulation::new(p, seed).run();
            let via_spec = Simulation::from_spec(p, &PolicySpec::default(), Rng::new(seed))
                .unwrap()
                .run();
            assert_eq!(via_new, via_spec, "config {i} seed {seed} diverged");
        }
    }
}

#[test]
fn batched_runner_is_byte_identical_to_fresh_runs() {
    // One runner reused across heterogeneous configs and seeds — buffer
    // reuse must leak nothing between runs.
    let spec = PolicySpec::default();
    let mut runner = ReplicationRunner::new();
    for (i, p) in config_zoo().iter().enumerate() {
        for seed in [7u64, 99] {
            let batched = runner.run(p, &spec, Rng::new(seed));
            let fresh = Simulation::with_rng(p, Rng::new(seed)).run();
            assert_eq!(batched, fresh, "config {i} seed {seed}: runner reuse leaked state");
        }
    }
}

#[test]
fn batched_runner_matches_for_every_policy_combo() {
    let p = Params::small_test();
    for selection in ["first_fit", "random", "locality"] {
        for repair in ["fifo", "lifo", "job_first"] {
            for failure in ["gang", "per_server"] {
                let spec = PolicySpec {
                    selection: selection.into(),
                    repair: repair.into(),
                    checkpoint: "auto".into(),
                    failure: failure.into(),
                };
                let mut runner = ReplicationRunner::new();
                let a = runner.run(&p, &spec, Rng::new(5));
                let b = runner.run(&p, &spec, Rng::new(5)); // reuse, same seed
                let fresh = Simulation::from_spec(&p, &spec, Rng::new(5)).unwrap().run();
                assert_eq!(a, b, "{selection}/{repair}/{failure} not deterministic");
                assert_eq!(a, fresh, "{selection}/{repair}/{failure} reuse diverged");
                assert!(a.completed, "{selection}/{repair}/{failure} did not finish");
            }
        }
    }
}

#[test]
fn sweep_outputs_identical_across_thread_counts() {
    // Beyond the mean: every collected metric must agree bit-for-bit
    // across thread counts (Summary sorts before reducing).
    let base = Params::small_test();
    let sweep = Sweep::one_way("t", "recovery_time", &[10.0, 30.0], 6, 17);
    let a = run_sweep(&base, &sweep, 1);
    let b = run_sweep(&base, &sweep, 4);
    for (pa, pb) in a.points.iter().zip(&b.points) {
        for metric in pa.collector.metrics() {
            let sa = pa.summary(metric).unwrap();
            let sb = pb.summary(metric).unwrap();
            assert_eq!(sa, sb, "metric {metric} diverged across thread counts");
        }
    }
}

// ------------------------------------------------------------------ //
// Golden snapshot (bless-on-first-run)
// ------------------------------------------------------------------ //

/// Bit-exact fingerprint of a run (floats as IEEE bit patterns).
fn fingerprint(o: &RunOutputs) -> String {
    format!(
        "makespan_bits={:016x}\n\
         completed={}\n\
         failures_total={}\n\
         failures_random={}\n\
         failures_systematic={}\n\
         preemptions={}\n\
         repairs_auto={}\n\
         repairs_manual={}\n\
         standby_swaps={}\n\
         host_selections={}\n\
         stall_time_bits={:016x}\n\
         recovery_total_bits={:016x}\n\
         events_delivered={}\n",
        o.makespan.to_bits(),
        o.completed,
        o.failures_total,
        o.failures_random,
        o.failures_systematic,
        o.preemptions,
        o.repairs_auto,
        o.repairs_manual,
        o.standby_swaps,
        o.host_selections,
        o.stall_time.to_bits(),
        o.recovery_total.to_bits(),
        o.events_delivered,
    )
}

/// The dispatch refactor (and any future one) must keep fixed-seed runs
/// byte-identical to the recorded snapshot. The golden file is written on
/// first run ("blessed") and compared exactly afterwards; delete it
/// deliberately when a behavior change is intended.
///
/// NOTE: the cross-commit guard only bites once a blessed
/// `tests/golden/small_test_seed42.txt` is **committed** — on a fresh
/// checkout (e.g. CI) this test self-blesses and passes vacuously.
/// First session with a Rust toolchain: run the suite once and commit
/// the generated file (tracked on the ROADMAP).
#[test]
fn golden_snapshot_small_test_seed_42() {
    let p = Params::small_test();
    let got = fingerprint(&Simulation::new(&p, 42).run());

    let dir = std::path::Path::new("tests/golden");
    let path = dir.join("small_test_seed42.txt");
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            got, want,
            "fixed-seed run diverged from the golden snapshot at {path:?}; \
             if this change is intentional, delete the file to re-bless"
        ),
        Err(_) => {
            std::fs::create_dir_all(dir).expect("create tests/golden");
            std::fs::write(&path, &got).expect("bless golden snapshot");
            eprintln!("blessed new golden snapshot at {path:?}");
        }
    }
}
