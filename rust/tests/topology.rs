//! Topology-subsystem acceptance suite.
//!
//! * the headline result: under switch-level correlated outages,
//!   `anti_affinity` placement suffers strictly fewer whole-job
//!   interruptions than `locality` on the same master streams (CRN);
//! * correlated outages flow end-to-end: trace events, per-domain
//!   metrics, repairs of idle victims, conservation invariants;
//! * the batched runner stays byte-identical to fresh construction with
//!   a topology configured;
//! * no `topology:` block = byte-identical legacy behavior (the `auto`
//!   failure model must not wrap, and no domain event may ever fire).

use airesim::config::{Params, TopologyLevelSpec, TopologySpec};
use airesim::model::cluster::{ReplicationRunner, Simulation};
use airesim::model::PolicySpec;
use airesim::scenario::Scenario;
use airesim::sim::rng::Rng;
use airesim::trace::TraceKind;

fn topo(levels: &[(&str, u32, f64)]) -> TopologySpec {
    TopologySpec {
        levels: levels
            .iter()
            .map(|&(name, size, outage_rate)| TopologyLevelSpec {
                name: name.into(),
                size,
                outage_rate,
            })
            .collect(),
    }
}

/// The scenario_topology.yaml cluster, rates stripped to isolate domain
/// outages: 96 working + 16 spare in racks of 4, switches of 16 servers;
/// only the switch level carries an outage rate. Base failure clocks are
/// off, repairs are fast and reliable — every disruption in a run comes
/// from a domain event.
fn switch_cluster() -> Params {
    let mut p = Params::small_test();
    p.job_size = 24;
    p.warm_standbys = 12;
    p.working_pool = 96;
    p.spare_pool = 16;
    p.job_len = 4.0 * 1440.0;
    p.random_failure_rate = 0.0;
    p.systematic_failure_rate = 0.0;
    p.systematic_fraction = 0.0;
    p.auto_repair_prob = 1.0;
    p.auto_repair_fail_prob = 0.0;
    p.auto_repair_time = 60.0;
    p.max_sim_time = 1e9;
    p.topology = Some(topo(&[("rack", 4, 0.0), ("switch", 4, 0.5 / 1440.0)]));
    p
}

fn with_selection(sel: &str) -> PolicySpec {
    PolicySpec { selection: sel.into(), ..PolicySpec::default() }
}

/// The acceptance headline: anti-affinity spreads each gang thin enough
/// that warm standbys absorb a switch blast, while locality concentrates
/// the gang into one or two switch domains and eats whole-job
/// interruptions — strictly fewer for anti-affinity on the same master
/// streams.
#[test]
fn anti_affinity_takes_strictly_fewer_whole_job_interruptions_than_locality() {
    let p = switch_cluster();
    let mut runner = ReplicationRunner::new();
    let (mut loc_interruptions, mut anti_interruptions) = (0u64, 0u64);
    let (mut loc_outages, mut anti_outages) = (0u64, 0u64);
    for seed in 1..=5u64 {
        let loc = runner.run(&p, &with_selection("locality"), Rng::new(seed));
        let anti = runner.run(&p, &with_selection("anti_affinity"), Rng::new(seed));
        assert!(loc.completed && anti.completed, "seed {seed}: both must finish");
        loc_interruptions += loc.domain_job_interruptions;
        anti_interruptions += anti.domain_job_interruptions;
        loc_outages += loc.domain_failures;
        anti_outages += anti.domain_failures;
    }
    assert!(loc_outages > 0 && anti_outages > 0, "outages must actually fire");
    assert!(
        anti_interruptions < loc_interruptions,
        "anti-affinity must take strictly fewer whole-job interruptions: \
         anti {anti_interruptions} vs locality {loc_interruptions} \
         (outages: {anti_outages} vs {loc_outages})"
    );
}

#[test]
fn domain_outages_produce_trace_events_and_metrics() {
    let p = switch_cluster();
    let (out, trace) = Simulation::from_spec(&p, &with_selection("locality"), Rng::new(7))
        .unwrap()
        .with_trace()
        .run_traced();
    assert!(out.domain_failures > 0, "outages fired");
    let traced = trace.count(|k| matches!(k, TraceKind::DomainFailure { .. }));
    assert_eq!(traced as u64, out.domain_failures, "one trace event per outage");
    // Event payloads stay inside the topology.
    for r in &trace.records {
        if let TraceKind::DomainFailure { level, domain_id, servers_hit } = r.kind {
            assert!(level < 2);
            assert!(domain_id < 28, "28 rack / 7 switch domains over 112 servers");
            assert!(servers_hit <= 16, "switch blast radius is 16");
        }
    }
    // The NDJSON schema carries the ISSUE's field names.
    let nd = trace.to_ndjson();
    assert!(nd.contains(r#""event":"domain_failure""#), "{nd}");
    assert!(nd.contains(r#""domain_id":"#) && nd.contains(r#""servers_hit":"#), "{nd}");
    // Blast accounting is consistent.
    assert!(out.domain_max_blast <= 16);
    assert!(out.domain_servers_lost >= out.domain_max_blast);
    // With base clocks off, every repair stems from a domain outage.
    assert!(out.repairs_auto > 0, "victims go through the repair pipeline");
    assert_eq!(out.failures_total, 0, "no per-server clock ever fired");
}

#[test]
fn idle_servers_fall_with_their_domain() {
    // A 1-server job on a 96-server fabric: almost every outage victim is
    // an idle server, and they must cycle through repair cleanly.
    let mut p = switch_cluster();
    p.job_size = 1;
    p.warm_standbys = 0;
    let out = Simulation::from_spec(&p, &PolicySpec::default(), Rng::new(3))
        .unwrap()
        .run();
    assert!(out.completed);
    assert!(out.domain_failures > 0);
    assert!(out.domain_servers_lost > 0);
    assert!(out.repairs_auto > 0, "idle victims repaired");
}

#[test]
fn conservation_holds_through_domain_outages() {
    for sel in ["locality", "anti_affinity", "first_fit"] {
        let p = switch_cluster();
        let mut sim =
            Simulation::from_spec(&p, &with_selection(sel), Rng::new(11)).unwrap();
        sim.prime();
        let mut steps = 0usize;
        while sim.step() && steps < 20_000 {
            steps += 1;
            assert!(sim.conservation_ok(), "{sel}: conservation broke at step {steps}");
        }
    }
}

#[test]
fn batched_runner_matches_fresh_with_topology() {
    let p = switch_cluster();
    for sel in ["locality", "anti_affinity", "power_of_two_choices"] {
        let spec = with_selection(sel);
        let mut runner = ReplicationRunner::new();
        for seed in [5u64, 21] {
            let batched = runner.run(&p, &spec, Rng::new(seed));
            let fresh = Simulation::from_spec(&p, &spec, Rng::new(seed)).unwrap().run();
            assert_eq!(batched, fresh, "{sel} seed {seed}: runner reuse diverged");
        }
    }
}

#[test]
fn no_topology_keeps_legacy_models_and_outputs() {
    let p = Params::small_test();
    assert!(p.topology.is_none());
    // `auto` must resolve to the plain gang model (no correlated wrapper),
    // byte-identical to naming it explicitly.
    let auto = Simulation::from_spec(&p, &PolicySpec::default(), Rng::new(42))
        .unwrap()
        .run();
    let gang_spec = PolicySpec { failure: "gang".into(), ..PolicySpec::default() };
    let gang = Simulation::from_spec(&p, &gang_spec, Rng::new(42)).unwrap().run();
    assert_eq!(auto, gang, "auto must not wrap without a topology");
    // And no domain accounting can ever move.
    assert_eq!(auto.domain_failures, 0);
    assert_eq!(auto.domain_servers_lost, 0);
    assert_eq!(auto.domain_job_interruptions, 0);
    assert_eq!(auto.domain_downtime, 0.0);
}

#[test]
fn scenario_yaml_carries_the_topology_block() {
    let text = "scenario: single\nseed: 3\n\
                params:\n  job_size: 24\n  warm_standbys: 12\n  working_pool: 96\n  spare_pool: 16\n  job_len: 1440\n  random_failure_rate: 0\n  systematic_failure_rate: 0\n  systematic_fraction: 0\n  max_sim_time: 1e9\n\
                topology:\n  servers_per_rack: 4\n  racks_per_switch: 4\n  switch_outage_rate: 0.5/1440\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let t = sc.params.topology.as_ref().expect("topology parsed into params");
    assert_eq!(t.levels.len(), 2);
    assert_eq!(t.levels[1].name, "switch");
    match sc.run().unwrap() {
        airesim::scenario::ScenarioOutcome::Single { outputs, .. } => {
            assert!(outputs.completed);
            assert!(outputs.domain_failures > 0, "scenario runs with domain outages");
        }
        _ => panic!("expected Single outcome"),
    }
}

#[test]
fn policy_axis_sweep_supports_the_new_selection_policies() {
    let text = "scenario: sweep\nseed: 42\nreplications: 2\n\
                params:\n  job_size: 24\n  warm_standbys: 12\n  working_pool: 96\n  spare_pool: 16\n  job_len: 1440\n  random_failure_rate: 0\n  systematic_failure_rate: 0\n  systematic_fraction: 0\n  max_sim_time: 1e9\n\
                topology:\n  servers_per_rack: 4\n  racks_per_switch: 4\n  switch_outage_rate: 0.5/1440\n\
                sweep:\n  kind: one_way\n  x: { name: policies.selection, values: [locality, anti_affinity, power_of_two_choices] }\n";
    let sc = Scenario::from_yaml(text).unwrap();
    match sc.run().unwrap() {
        airesim::scenario::ScenarioOutcome::Sweep(result) => {
            assert_eq!(result.points.len(), 3);
            assert_eq!(
                result.points[1].point.label(),
                "policies.selection=anti_affinity"
            );
            for pr in &result.points {
                assert_eq!(pr.summary("domain_failures").unwrap().n, 2);
            }
        }
        _ => panic!("expected Sweep outcome"),
    }
}

#[test]
fn anti_affinity_without_topology_is_rejected_at_parse_time() {
    let text = "scenario: single\npolicies:\n  selection: anti_affinity\n";
    let err = Scenario::from_yaml(text).unwrap_err();
    assert!(err.contains("topology"), "{err}");
    // Same for a sweep axis hitting the policy (validate pre-flights).
    let text = "scenario: sweep\nreplications: 1\n\
                sweep:\n  kind: one_way\n  x: { name: policies.selection, values: [anti_affinity] }\n";
    let sc = Scenario::from_yaml(text).unwrap();
    let err = sc.run().unwrap_err();
    assert!(err.contains("topology"), "{err}");
}

#[test]
fn shipped_topology_scenario_config_runs() {
    let text = std::fs::read_to_string("configs/scenario_topology.yaml").unwrap();
    let sc = Scenario::from_yaml(&text).unwrap();
    let t = sc.params.topology.as_ref().expect("topology block");
    assert!(t.has_outages());
    // Scaled-down execution: fewer replications, same mechanics.
    let mut sc = sc;
    match &mut sc.kind {
        airesim::scenario::ScenarioKind::Sweep(sweep) => sweep.replications = 2,
        _ => panic!("scenario_topology.yaml must be a sweep"),
    }
    match sc.run().unwrap() {
        airesim::scenario::ScenarioOutcome::Sweep(result) => {
            assert_eq!(result.points.len(), 2);
            for pr in &result.points {
                assert!(pr.summary("makespan").unwrap().mean > 0.0);
            }
        }
        _ => panic!("expected Sweep outcome"),
    }
}
