//! Acceptance suite for the thinned aggregate failure clocks.
//!
//! The thinned model replaces N per-server renewal timers with ONE
//! Poisson candidate clock per gang drawn against a majorizing hazard
//! envelope (Lewis–Shedler thinning). Correctness is *statistical*
//! equivalence with the per-server reference — same failure process in
//! distribution, not draw-for-draw — so the oracles here are means and
//! spreads over many common-random-number replications:
//!
//! * mean makespan, mean failure count, mean uninterrupted-burst length
//!   (the inter-arrival proxy), and the failure-count spread must agree
//!   between `thinned` and `per_server` for Weibull and LogNormal fleets;
//! * on an exponential fleet (where thinning never rejects) the same
//!   bars hold against the exact `gang` fast path;
//! * the whole point: `events_scheduled` must collapse — ≥5× fewer
//!   scheduled events than per-server timers on a wide Weibull gang;
//! * the PR-3 `CorrelatedFailures` wrapper composes unchanged: `auto` on
//!   a rated topology + Weibull clocks builds `correlated(thinned)`.

use airesim::config::{DistKind, Params, TopologyLevelSpec, TopologySpec};
use airesim::model::cluster::Simulation;
use airesim::model::{PolicySpec, RunOutputs};
use airesim::sim::rng::Rng;

/// A busy little fleet: failures are frequent relative to the job length,
/// so every replication sees dozens of interrupts in every subsystem.
fn fleet(dist: DistKind) -> Params {
    let mut p = Params::small_test();
    p.job_size = 32;
    p.working_pool = 40;
    p.warm_standbys = 4;
    p.spare_pool = 8;
    p.job_len = 2880.0;
    p.max_sim_time = 1e9;
    p.failure_dist = dist;
    p
}

fn run_one(p: &Params, failure: &str, rng: Rng) -> RunOutputs {
    let mut spec = PolicySpec::default();
    spec.set("failure", failure).unwrap();
    Simulation::from_spec(p, &spec, rng).unwrap().run()
}

struct ArmStats {
    mean_makespan: f64,
    mean_failures: f64,
    std_failures: f64,
    mean_burst: f64,
}

fn arm_stats(p: &Params, failure: &str, arm: u64, reps: u64) -> ArmStats {
    let mut makespans = Vec::new();
    let mut failures = Vec::new();
    let mut bursts = Vec::new();
    for r in 0..reps {
        let o = run_one(p, failure, Rng::derived(7, &[arm, r]));
        assert!(o.completed, "{failure} rep {r} did not complete");
        makespans.push(o.makespan);
        failures.push(o.failures_total as f64);
        bursts.push(o.avg_run_duration);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let mf = mean(&failures);
    let var =
        failures.iter().map(|x| (x - mf) * (x - mf)).sum::<f64>() / (reps - 1) as f64;
    ArmStats {
        mean_makespan: mean(&makespans),
        mean_failures: mf,
        std_failures: var.sqrt(),
        mean_burst: mean(&bursts),
    }
}

fn assert_close(what: &str, a: f64, b: f64, tol: f64) {
    let rel = (a - b).abs() / b.abs().max(1e-12);
    assert!(rel < tol, "{what} diverged: {a} vs {b} (rel {rel:.3}, tol {tol})");
}

/// The tentpole oracle: thinned and per-server clocks are draws from the
/// same failure process. Checked on both non-exponential families the
/// `auto` router sends to `thinned`.
#[test]
fn thinned_matches_per_server_statistically() {
    for (arm, dist) in [
        (0u64, DistKind::Weibull { shape: 1.5 }),
        (2, DistKind::LogNormal { sigma: 0.8 }),
    ] {
        let p = fleet(dist);
        let reps = 60;
        let thin = arm_stats(&p, "thinned", arm, reps);
        let per = arm_stats(&p, "per_server", arm + 1, reps);
        let tag = format!("{dist:?}");
        assert_close(&format!("{tag} mean makespan"), thin.mean_makespan, per.mean_makespan, 0.05);
        assert_close(&format!("{tag} mean failures"), thin.mean_failures, per.mean_failures, 0.10);
        assert_close(&format!("{tag} mean burst"), thin.mean_burst, per.mean_burst, 0.10);
        // Spread too: equal means with the wrong inter-arrival shape would
        // show up as a different failure-count dispersion.
        assert_close(&format!("{tag} failures spread"), thin.std_failures, per.std_failures, 0.35);
        // Sanity: the runs actually exercised the clocks.
        assert!(thin.mean_failures > 10.0, "{tag}: too few failures to compare");
    }
}

/// On exponential clocks the envelope is exact (H == Λ, no rejections),
/// so thinned must also agree with the legacy gang fast path.
#[test]
fn thinned_matches_gang_on_exponential_fleets() {
    let p = fleet(DistKind::Exponential);
    let reps = 60;
    let thin = arm_stats(&p, "thinned", 10, reps);
    let gang = arm_stats(&p, "gang", 11, reps);
    assert_close("exp mean makespan", thin.mean_makespan, gang.mean_makespan, 0.05);
    assert_close("exp mean failures", thin.mean_failures, gang.mean_failures, 0.10);
    assert_close("exp mean burst", thin.mean_burst, gang.mean_burst, 0.10);
}

/// The perf claim, as a hard functional bar: one aggregate clock per gang
/// schedules at least 5× fewer events than one timer per server on a
/// wide Weibull gang (the acceptance threshold from the PR issue; at
/// 10k servers the bench shows far more — see BENCH_PR6.json).
#[test]
fn thinned_schedules_far_fewer_events() {
    let mut p = fleet(DistKind::Weibull { shape: 1.5 });
    p.job_size = 256;
    p.working_pool = 288;
    p.warm_standbys = 8;
    p.spare_pool = 32;
    let thin = run_one(&p, "thinned", Rng::new(42));
    let per = run_one(&p, "per_server", Rng::new(42));
    assert!(thin.completed && per.completed);
    assert!(
        per.events_scheduled >= 5 * thin.events_scheduled,
        "expected ≥5× fewer scheduled events: thinned {} vs per_server {}",
        thin.events_scheduled,
        per.events_scheduled
    );
    // The ledger itself must be coherent: everything delivered was
    // scheduled (lazy cancellation means not everything scheduled is
    // delivered before the run ends).
    assert!(thin.events_delivered <= thin.events_scheduled);
    assert!(per.events_delivered <= per.events_scheduled);
}

/// Composition with PR-3 correlated outages: `auto` on a rated topology
/// with Weibull base clocks must wrap thinned clocks in
/// `CorrelatedFailures` — and the combined run must still complete with
/// both failure sources live.
#[test]
fn correlated_wrapper_composes_over_thinned_clocks() {
    let mut p = fleet(DistKind::Weibull { shape: 1.5 });
    p.job_size = 24;
    p.working_pool = 96;
    p.warm_standbys = 12;
    p.spare_pool = 16;
    p.topology = Some(TopologySpec {
        levels: vec![
            TopologyLevelSpec { name: "rack".into(), size: 4, outage_rate: 0.0 },
            TopologyLevelSpec {
                name: "switch".into(),
                size: 4,
                outage_rate: 0.5 / 1440.0,
            },
        ],
    });
    let set = PolicySpec::default().build(&p).unwrap();
    assert_eq!(set.failure.name(), "correlated");

    let o = Simulation::new(&p, 7).run();
    assert!(o.completed);
    assert!(o.failures_total > 0, "base (thinned) clocks never fired");
    assert!(o.domain_failures > 0, "correlated outage clocks never fired");
}
