//! End-to-end tests for `airesim serve`: drive the daemon's accept loop
//! with in-memory streams and check the tentpole guarantees — chunk
//! concatenation equals the CLI's stdout byte-for-byte, a repeated
//! request hits the warm fleet cache, malformed input never kills the
//! loop, and `route: auto` answers analytically.

use airesim::report::json::Json;
use airesim::report::Format;
use airesim::serve::daemon::{serve_loop, ServeOpts};
use airesim::serve::pipeline::{self, ExecRequest, Route};
use airesim::sweep::ctrl::ExecCtrl;
use airesim::testkit::parse_json;
use std::io::{BufReader, Cursor, Read, Write};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// A routable single-run scenario (exponential clocks, default policies,
/// no DES-only subsystems armed) — small enough that a test replication
/// finishes in milliseconds.
const DOC: &str = "scenario: single\n\
                   seed: 7\n\
                   params:\n\
                   \x20 job_size: 32\n\
                   \x20 working_pool: 40\n\
                   \x20 spare_pool: 8\n\
                   \x20 warm_standbys: 4\n\
                   \x20 job_len: 1440\n\
                   \x20 random_failure_rate: 0.5/1440\n\
                   \x20 systematic_failure_rate: 2.5/1440\n";

/// Build one NDJSON request line for [`DOC`].
fn request_line(id: &str, extra: &[(&str, Json)]) -> String {
    let mut fields =
        vec![("id".to_string(), Json::str(id)), ("scenario".to_string(), Json::str(DOC))];
    for (k, v) in extra {
        fields.push((k.to_string(), v.clone()));
    }
    Json::Obj(fields).render() + "\n"
}

fn jget<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

fn jstr(j: &Json) -> &str {
    match j {
        Json::Str(s) => s.as_str(),
        other => panic!("expected a string, got {other:?}"),
    }
}

/// Parse every response line addressed to `id`, in order.
fn lines_for(text: &str, id: &str) -> Vec<Json> {
    text.lines()
        .map(|l| parse_json(l).unwrap_or_else(|e| panic!("unparseable response `{l}`: {e}")))
        .filter(|j| jget(j, "id").map(|v| matches!(v, Json::Str(s) if s == id)) == Some(true))
        .collect()
}

/// Concatenate the `chunk` payloads for `id` — the serve equivalent of
/// the CLI's stdout for that request.
fn stream_of(text: &str, id: &str) -> String {
    lines_for(text, id)
        .iter()
        .filter_map(|j| jget(j, "chunk").map(jstr).map(str::to_string))
        .collect()
}

fn done_of(text: &str, id: &str) -> Json {
    lines_for(text, id)
        .into_iter()
        .find(|j| jget(j, "done").is_some())
        .unwrap_or_else(|| panic!("no done line for `{id}` in:\n{text}"))
}

fn cache_count(done: &Json, field: &str) -> f64 {
    match jget(jget(done, "cache").expect("cache object"), field) {
        Some(Json::Num(n)) => *n,
        other => panic!("cache.{field} missing or non-numeric: {other:?}"),
    }
}

/// What the CLI would print for [`DOC`] in `format` (the pipeline run
/// cold, exactly as `cmd_scenario` drives it).
fn cli_reference(format: Format) -> String {
    let req = ExecRequest {
        doc: DOC.to_string(),
        format,
        seed: None,
        threads: None,
        sets: None,
        policies: None,
        trace: false,
        route: Route::Des,
        origin: None,
    };
    let prep = pipeline::prepare(&req).expect("reference prepare");
    let result = pipeline::run_prepared(&prep, &ExecCtrl::default()).expect("reference run");
    pipeline::render(&prep, result)
}

/// Feed the daemon a fixed script all at once and return its full
/// response text (requests may run concurrently — fine when the
/// assertions don't depend on cache warmth).
fn serve_script(input: &str, threads: usize) -> String {
    let mut out = Vec::new();
    serve_loop(
        Cursor::new(input.to_string()),
        &mut out,
        &ServeOpts { threads, fleet_cache: 8 },
    )
    .expect("serve_loop io");
    String::from_utf8(out).expect("utf8 responses")
}

#[test]
fn chunks_concatenate_to_the_cli_output_in_every_format() {
    for format in [Format::Text, Format::Json, Format::Csv, Format::Ndjson] {
        let input = request_line("r", &[("format", Json::str(format.name()))]);
        let text = serve_script(&input, 2);
        let done = done_of(&text, "r");
        assert_eq!(jget(&done, "routed"), Some(&Json::Bool(false)));
        assert_eq!(jget(&done, "cancelled"), Some(&Json::Bool(false)));
        assert_eq!(
            stream_of(&text, "r"),
            cli_reference(format),
            "serve stream != CLI stdout for --format {}",
            format.name()
        );
    }
}

#[test]
fn malformed_lines_and_unknown_cancels_never_kill_the_loop() {
    let input = format!(
        "this is not json\n\n{{\"id\":\"bad\"}}\n{{\"cancel\":\"ghost\"}}\n{}",
        request_line("ok", &[])
    );
    let text = serve_script(&input, 2);

    // The garbage line answers with an un-addressed error object…
    let parse_errors: Vec<String> = text
        .lines()
        .map(|l| parse_json(l).unwrap())
        .filter(|j| jget(j, "id") == Some(&Json::Null))
        .map(|j| jstr(jget(&j, "error").expect("error field")).to_string())
        .collect();
    assert!(
        parse_errors.iter().any(|e| e.contains("bad request JSON")),
        "expected a parse error line, got {parse_errors:?}"
    );
    // …the id-only request errors under its own id…
    let bad = lines_for(&text, "bad");
    assert!(
        bad.iter().any(|j| jget(j, "error").is_some()),
        "missing-scenario request must answer an error"
    );
    // …cancelling an unknown id errors instead of acking…
    let ghost = lines_for(&text, "ghost");
    assert!(ghost.iter().any(|j| {
        jget(j, "error").map(jstr) == Some("no active request with this id")
    }));
    // …and the request behind all of them still completes normally.
    let done = done_of(&text, "ok");
    assert_eq!(jget(&done, "cancelled"), Some(&Json::Bool(false)));
    assert_eq!(stream_of(&text, "ok"), cli_reference(Format::Text));
}

#[test]
fn auto_route_answers_analytically() {
    let input = request_line(
        "fast",
        &[("route", Json::str("auto")), ("format", Json::str("json"))],
    );
    let text = serve_script(&input, 2);
    let done = done_of(&text, "fast");
    assert_eq!(jget(&done, "routed"), Some(&Json::Bool(true)), "done: {done:?}");
    let body = parse_json(stream_of(&text, "fast").trim_end()).expect("analytic json");
    assert_eq!(jget(&body, "kind").map(jstr), Some("analytic"));
    assert!(matches!(jget(&body, "makespan_est"), Some(Json::Num(_))));
}

// ---- sequenced warm-cache test: the second request must start only ----
// ---- after the first finishes, so its fleet fetch is a guaranteed ----
// ---- cache hit.                                                    ----

/// Reader fed line-by-line over a channel; EOF when the sender drops.
struct ChanReader {
    rx: mpsc::Receiver<Vec<u8>>,
    pending: Vec<u8>,
}

impl Read for ChanReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pending.is_empty() {
            match self.rx.recv() {
                Ok(bytes) => self.pending = bytes,
                Err(_) => return Ok(0),
            }
        }
        let n = buf.len().min(self.pending.len());
        buf[..n].copy_from_slice(&self.pending[..n]);
        self.pending.drain(..n);
        Ok(n)
    }
}

/// Writer into a shared buffer the test thread can watch live.
#[derive(Clone)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn wait_for_done(buf: &Arc<Mutex<Vec<u8>>>, id: &str) {
    for _ in 0..2000 {
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        // Only parse complete lines — a chunk may be mid-write.
        let upto = text.rfind('\n').map(|i| &text[..i]).unwrap_or("");
        if upto
            .lines()
            .filter_map(|l| parse_json(l).ok())
            .any(|j| {
                jget(&j, "done").is_some()
                    && jget(&j, "id") == Some(&Json::Str(id.to_string()))
            })
        {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    panic!("request `{id}` never finished");
}

#[test]
fn a_repeated_request_is_byte_identical_and_skips_the_fleet_build() {
    let (tx, rx) = mpsc::channel::<Vec<u8>>();
    let buf = Arc::new(Mutex::new(Vec::new()));
    let writer = SharedBuf(Arc::clone(&buf));
    let server = std::thread::spawn(move || {
        serve_loop(
            BufReader::new(ChanReader { rx, pending: Vec::new() }),
            writer,
            &ServeOpts { threads: 2, fleet_cache: 8 },
        )
        .expect("serve_loop io")
    });

    let req = |id: &str| request_line(id, &[("format", Json::str("ndjson"))]).into_bytes();
    tx.send(req("first")).unwrap();
    wait_for_done(&buf, "first");
    tx.send(req("again")).unwrap();
    wait_for_done(&buf, "again");
    drop(tx); // EOF: the accept loop joins its handlers and returns
    server.join().unwrap();

    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    let (first, again) = (stream_of(&text, "first"), stream_of(&text, "again"));
    assert!(!first.is_empty());
    assert_eq!(first, again, "warm rerun must stream identical bytes");
    assert_eq!(first, cli_reference(Format::Ndjson), "stream != CLI stdout");

    let cold = done_of(&text, "first");
    assert!(cache_count(&cold, "fleet_misses") >= 1.0, "cold run builds the fleet");
    assert_eq!(cache_count(&cold, "fleet_hits"), 0.0, "nothing cached yet");
    let warm = done_of(&text, "again");
    assert!(cache_count(&warm, "fleet_hits") >= 1.0, "warm rerun must hit: {warm:?}");
    assert_eq!(cache_count(&warm, "fleet_misses"), 0.0, "warm rerun rebuilt the fleet");

    // The fingerprints agree — same doc, same plan key.
    assert_eq!(jget(&cold, "fingerprint"), jget(&warm, "fingerprint"));
}
