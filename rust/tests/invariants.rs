//! Property-based invariants over randomized configurations (testkit is
//! the in-repo proptest substitute; failures print a reproducing seed).

use airesim::config::{validate, DistKind, Params};
use airesim::model::cluster::Simulation;
use airesim::testkit::{check, Gen};

/// Draw a random-but-valid small configuration.
fn random_params(g: &mut Gen) -> Params {
    let mut p = Params::small_test();
    p.job_size = g.usize_in(8, 64) as u32;
    p.warm_standbys = g.usize_in(0, 8) as u32;
    p.working_pool = p.job_size + g.usize_in(0, 16) as u32;
    p.spare_pool = g.usize_in(0, 16) as u32;
    // Keep feasible: pools must at least cover the job.
    if p.working_pool + p.spare_pool < p.job_size {
        p.spare_pool = p.job_size - p.working_pool;
    }
    p.random_failure_rate = g.f64_in(0.0, 2.0) / (24.0 * 60.0);
    p.systematic_failure_rate = g.f64_in(0.0, 10.0) / (24.0 * 60.0);
    p.systematic_fraction = g.f64_in(0.0, 0.4);
    p.job_len = g.f64_in(60.0, 3.0 * 1440.0);
    p.recovery_time = g.f64_in(1.0, 60.0);
    p.host_selection_time = g.f64_in(0.0, 15.0);
    p.waiting_time = g.f64_in(0.0, 60.0);
    p.auto_repair_prob = g.prob();
    p.auto_repair_fail_prob = g.prob();
    p.manual_repair_fail_prob = g.prob();
    p.auto_repair_time = g.f64_in(5.0, 600.0);
    p.manual_repair_time = g.f64_in(60.0, 5.0 * 1440.0);
    p.diagnosis_prob = g.prob();
    p.diagnosis_uncertainty = g.prob() * 0.5;
    p.retirement_threshold = g.usize_in(0, 4) as u32;
    p.retirement_window = g.f64_in(100.0, 1e5);
    if g.bool() {
        p.bad_regen_interval = g.f64_in(100.0, 2000.0);
        p.bad_regen_fraction = g.prob() * 0.05;
    }
    p.max_sim_time = 60.0 * 1440.0;
    match g.usize_in(0, 2) {
        0 => p.failure_dist = DistKind::Exponential,
        1 => p.failure_dist = DistKind::Weibull { shape: g.f64_in(0.5, 3.0) },
        _ => p.failure_dist = DistKind::LogNormal { sigma: g.f64_in(0.2, 1.5) },
    }
    validate::validate(&p).expect("generated params must validate");
    p
}

#[test]
fn conservation_holds_at_every_event() {
    check("server conservation", 40, |g| {
        let p = random_params(g);
        let mut sim = Simulation::new(&p, g.seed());
        sim.prime();
        assert!(sim.conservation_ok(), "violated at t=0");
        let mut steps = 0;
        while sim.step() {
            steps += 1;
            if steps % 16 == 0 {
                assert!(
                    sim.conservation_ok(),
                    "violated at t={} after {steps} events",
                    sim.now()
                );
            }
            if steps > 200_000 {
                break;
            }
        }
        assert!(sim.conservation_ok(), "violated at end");
    });
}

#[test]
fn clock_is_monotone_and_job_progress_bounded() {
    check("monotone clock, bounded progress", 40, |g| {
        let p = random_params(g);
        let mut sim = Simulation::new(&p, g.seed());
        sim.prime();
        let mut last = 0.0;
        let mut steps = 0;
        loop {
            let rem = sim.job().remaining;
            assert!(
                rem >= -1e-9 && rem <= p.job_len + 1e-9,
                "remaining {rem} outside [0, {}]",
                p.job_len
            );
            let now = sim.now();
            assert!(now >= last, "clock went backwards: {now} < {last}");
            last = now;
            steps += 1;
            if steps > 200_000 || !sim.step() {
                break;
            }
        }
    });
}

#[test]
fn outputs_are_internally_consistent() {
    check("output consistency", 60, |g| {
        let p = random_params(g);
        let o = Simulation::new(&p, g.seed()).run();
        assert_eq!(o.failures_total, o.failures_random + o.failures_systematic);
        assert!(o.makespan >= 0.0 && o.makespan <= p.max_sim_time + 1e-6);
        if o.completed {
            // A finished job spent at least its failure-free length.
            assert!(
                o.makespan + 1e-6 >= p.job_len,
                "makespan {} < job_len {}",
                o.makespan,
                p.job_len
            );
        }
        // Every failure is resolved one way: swap, selection, or in-place.
        assert_eq!(
            o.failures_total,
            o.standby_swaps + o.host_selections + o.undiagnosed,
            "failure resolutions don't add up"
        );
        // Recovery accounting: one recovery per failure, plus possibly one
        // per selection-restart (standby path + selection path both pay).
        assert!(o.recovery_total <= (o.failures_total as f64 + 1.0) * p.recovery_time + 1e-6);
        assert!(o.stall_time >= 0.0);
        assert!(o.preemption_cost >= 0.0);
        if p.retirement_threshold == 0 {
            assert_eq!(o.retirements, 0);
        }
        if p.diagnosis_uncertainty == 0.0 {
            assert_eq!(o.wrong_diagnoses, 0);
        }
    });
}

#[test]
fn zero_failure_rates_always_complete_exactly() {
    check("zero-rate exactness", 30, |g| {
        let mut p = random_params(g);
        p.random_failure_rate = 0.0;
        p.systematic_failure_rate = 0.0;
        p.bad_regen_interval = 0.0;
        let o = Simulation::new(&p, g.seed()).run();
        assert!(o.completed);
        assert_eq!(o.failures_total, 0);
        assert!((o.makespan - (p.host_selection_time + p.job_len)).abs() < 1e-6);
    });
}

#[test]
fn more_failures_never_shorten_the_job() {
    // Stochastic monotonicity in the failure rate (checked on means over
    // a few replications to damp noise).
    check("rate monotonicity", 8, |g| {
        let mut p = random_params(g);
        p.bad_regen_interval = 0.0;
        p.failure_dist = DistKind::Exponential;
        p.max_sim_time = 1e7;
        let reps = 10;
        let mean = |rate_scale: f64, seed: u64| -> f64 {
            let mut q = p.clone();
            q.random_failure_rate *= rate_scale;
            q.systematic_failure_rate *= rate_scale;
            (0..reps)
                .map(|r| {
                    Simulation::with_rng(
                        &q,
                        airesim::sim::rng::Rng::derived(seed, &[r]),
                    )
                    .run()
                    .makespan
                })
                .sum::<f64>()
                / reps as f64
        };
        let seed = g.seed();
        let lo = mean(0.2, seed);
        let hi = mean(5.0, seed);
        assert!(
            hi + 1e-6 >= lo,
            "5x failure rate shortened the job: {hi} < {lo}"
        );
    });
}
