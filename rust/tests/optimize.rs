//! Acceptance suite for the optimization subsystem
//! (`scenario: optimize`) and the paired-CRN statistics under it:
//!
//! * the paired-CI machinery matches hand-computed fixtures and is
//!   degenerate-safe (zero variance -> zero width, never NaN);
//! * on real simulator outputs, CRN pairing yields strictly narrower
//!   intervals than the unpaired Welch fallback;
//! * the shipped screen config runs and emits a ranked knob table in
//!   all four formats;
//! * a rigged tune finds a winner that beats the base config with a
//!   paired CI excluding zero, and its `--best-out` YAML re-parses and
//!   runs as a `scenario: single`;
//! * optimize output is byte-identical across repeated runs and worker
//!   thread counts.

use airesim::config::Params;
use airesim::model::cluster::ReplicationRunner;
use airesim::model::PolicySpec;
use airesim::optimize::stats::{mean_ci, paired_delta_ci, welch_delta_ci};
use airesim::report::json::Json;
use airesim::report::{Format, Sink};
use airesim::scenario::{Scenario, ScenarioKind, ScenarioOutcome};
use airesim::sim::rng::Rng;
use airesim::sweep::CRN_STREAM;
use airesim::testkit::parse_json;

const SMALL: &str = "params:\n  job_size: 32\n  working_pool: 40\n  spare_pool: 8\n  warm_standbys: 4\n  job_len: 1440\n  random_failure_rate: 0.5/1440\n  systematic_failure_rate: 2.5/1440\n";

fn obj_get<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

// ---------------------------------------------------------------- stats

#[test]
fn paired_ci_matches_hand_computed_fixture() {
    // Deltas b - a = [1, 2, 3, 4, 5]: mean 3, sample var 2.5,
    // half-width = t(4) * sqrt(2.5 / 5) = 2.776 * 0.7071.
    let a = [10.0, 10.0, 10.0, 10.0, 10.0];
    let b = [11.0, 12.0, 13.0, 14.0, 15.0];
    let ci = paired_delta_ci(&a, &b).unwrap();
    assert_eq!(ci.n, 5);
    assert!((ci.mean - 3.0).abs() < 1e-12);
    assert!((ci.half - 2.776 * (2.5f64 / 5.0).sqrt()).abs() < 1e-9, "{}", ci.half);
    assert!(ci.significant());
}

#[test]
fn degenerate_variance_is_zero_width_not_nan() {
    // Identical series: delta 0 with zero spread. The CI must be an
    // honest zero-width interval, not NaN from 0/0.
    let a = [5.0, 5.0, 5.0, 5.0];
    let ci = paired_delta_ci(&a, &a).unwrap();
    assert_eq!(ci.mean, 0.0);
    assert_eq!(ci.half, 0.0);
    assert!(!ci.significant(), "a zero delta is not a significant delta");

    let m = mean_ci(&a).unwrap();
    assert_eq!(m.mean, 5.0);
    assert_eq!(m.half, 0.0);
}

/// The tentpole's statistical payoff, pinned on real simulator outputs:
/// two configs run on the same CRN streams share failure noise, so the
/// paired interval on their delta is strictly narrower than the
/// unpaired Welch interval over the same numbers.
#[test]
fn crn_pairing_beats_welch_on_simulator_outputs() {
    let base = Params::small_test();
    let mut varied = base.clone();
    varied.recovery_time = 60.0;
    let spec = PolicySpec::default();
    let mut runner = ReplicationRunner::new();
    let run = |runner: &mut ReplicationRunner, p: &Params, r: u64| {
        runner.run(p, &spec, Rng::derived(42, &[CRN_STREAM, r])).makespan / 60.0
    };
    let reps = 8;
    let a: Vec<f64> = (0..reps).map(|r| run(&mut runner, &base, r)).collect();
    let b: Vec<f64> = (0..reps).map(|r| run(&mut runner, &varied, r)).collect();

    let paired = paired_delta_ci(&a, &b).unwrap();
    let welch = welch_delta_ci(&a, &b).unwrap();
    assert!((paired.mean - welch.mean).abs() < 1e-9, "same point estimate");
    assert!(
        paired.half < welch.half,
        "CRN pairing must shrink the interval: paired ±{} vs welch ±{}",
        paired.half,
        welch.half
    );
}

// --------------------------------------------------------------- screen

#[test]
fn shipped_screen_config_emits_a_ranked_knob_table_in_all_formats() {
    let text = std::fs::read_to_string("configs/scenario_optimize.yaml").unwrap();
    let sc = Scenario::from_yaml(&text).unwrap();
    assert!(matches!(sc.kind, ScenarioKind::Optimize(_)));
    let outcome = sc.run().unwrap();
    let ScenarioOutcome::Optimize(rec) = &outcome else { panic!("expected Optimize") };
    assert_eq!(rec.mode, "screen");
    assert_eq!(rec.effects.len(), 3);
    assert_eq!(rec.total_runs, 8 * 4, "2N x reps for k=3 knobs");
    // Ranked 1..=k by |effect| descending.
    for (i, e) in rec.effects.iter().enumerate() {
        assert_eq!(e.rank, i + 1);
        assert!(e.n > 0);
        assert!(e.ci95.is_finite());
        if i > 0 {
            assert!(
                rec.effects[i - 1].effect.abs() >= e.effect.abs(),
                "effects out of rank order"
            );
        }
    }
    let record = sc.record(&outcome);

    // Text: the ranked table with CI and significance columns.
    let txt = Format::Text.sink().scenario(&record);
    assert!(txt.contains("== scenario:"), "{txt}");
    assert!(txt.contains("knob importance"), "{txt}");
    assert!(txt.contains("±95%CI"), "{txt}");
    for knob in ["checkpoint_interval", "recovery_time", "policies.selection"] {
        assert!(txt.contains(knob), "text misses knob {knob}: {txt}");
    }

    // JSON: one document, ranked effects under result.effects.
    let doc = parse_json(Format::Json.sink().scenario(&record).trim_end()).unwrap();
    assert_eq!(obj_get(&doc, "scenario"), Some(&Json::str("optimize")));
    let result = obj_get(&doc, "result").unwrap();
    assert_eq!(obj_get(result, "mode"), Some(&Json::str("screen")));
    let Some(Json::Arr(effects)) = obj_get(result, "effects") else { panic!() };
    assert_eq!(effects.len(), 3);
    for e in effects {
        for key in ["rank", "knob", "lo", "hi", "effect", "ci95", "n", "significant"] {
            assert!(obj_get(e, key).is_some(), "effect json missing {key}");
        }
    }

    // CSV: one row per ranked knob.
    let csv = Format::Csv.sink().scenario(&record);
    let mut lines = csv.lines();
    assert_eq!(lines.next().unwrap(), "rank,knob,lo,hi,effect,ci95,n,significant");
    assert_eq!(csv.trim_end().lines().count(), 1 + 3);
    assert!(csv.contains("\n1,"), "{csv}");

    // NDJSON: a summary line plus one typed line per effect.
    let nd = Format::Ndjson.sink().scenario(&record);
    let mut summary = 0usize;
    let mut effect_lines = 0usize;
    for line in nd.trim_end().lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("bad line `{line}`: {e}"));
        match obj_get(&doc, "type") {
            Some(Json::Str(t)) if t == "optimize" => summary += 1,
            Some(Json::Str(t)) if t == "effect" => effect_lines += 1,
            _ => {}
        }
    }
    assert_eq!(summary, 1);
    assert_eq!(effect_lines, 3);
}

// ----------------------------------------------------------------- tune

/// A deliberately rigged search space: the base config checkpoints once
/// per job length (max work loss per failure) and the grid offers two
/// poor intervals plus one clearly better one. The winner must beat the
/// base on CRN-paired seeds with a CI excluding zero.
fn rigged_tune_yaml() -> String {
    format!(
        "scenario: optimize\ntitle: rigged tune\nseed: 11\nreplications: 4\n{SMALL}\
         \x20 checkpoint_interval: 1440\n  checkpoint_cost: 5\n\
         policies:\n  checkpoint: periodic\n\
         optimize:\n  mode: tune\n  objective: makespan_hours\n  direction: min\n  knobs:\n\
         \x20   - param: checkpoint_interval\n      values: [30, 720, 1440]\n"
    )
}

fn run_tune(threads: usize) -> airesim::report::OptimizeRecord {
    let mut sc = Scenario::from_yaml(&rigged_tune_yaml()).unwrap();
    sc.threads = threads;
    match sc.run().unwrap() {
        ScenarioOutcome::Optimize(rec) => rec,
        _ => panic!("expected Optimize outcome"),
    }
}

#[test]
fn tune_winner_beats_base_with_significant_paired_ci() {
    let rec = run_tune(0);
    assert_eq!(rec.mode, "tune");
    // Trail covers every candidate in declaration order: base + grid.
    assert_eq!(rec.trail.len(), 4);
    assert_eq!(rec.trail[0].label, "base");
    assert_eq!(rec.trail[1].label, "checkpoint_interval=30");
    assert_eq!(rec.trail[3].label, "checkpoint_interval=1440");
    assert!(rec.total_runs <= rec.budget);

    let best = rec.best.as_ref().expect("tune always names a winner");
    assert_ne!(best.label, "base", "a 1440-min interval must not win");
    assert!(best.delta_mean < 0.0, "winner improves the objective (min)");
    assert!(
        best.significant,
        "paired CI must exclude zero: delta {} ±{} over n {}",
        best.delta_mean, best.delta_ci95, best.delta_n
    );
    assert!(best.delta_mean + best.delta_ci95 < 0.0, "CI strictly below zero");
    assert_eq!(
        best.delta_n, rec.replications,
        "the base control arm rides to the full replication count"
    );
    // Exactly one trail point is flagged as the winner, and it is best's.
    let winners: Vec<_> = rec.trail.iter().filter(|t| t.winner).collect();
    assert_eq!(winners.len(), 1);
    assert_eq!(winners[0].label, best.label);
}

#[test]
fn tune_best_yaml_reparses_and_runs_as_single() {
    let rec = run_tune(0);
    let best = rec.best.as_ref().unwrap();
    let sc = Scenario::from_yaml(&best.yaml).expect("emitted YAML parses");
    assert!(matches!(sc.kind, ScenarioKind::Single { .. }));
    // The winner's knob setting survived the round trip.
    let winner_interval: f64 = best.label.strip_prefix("checkpoint_interval=").unwrap().parse().unwrap();
    assert_eq!(sc.params.checkpoint_interval, winner_interval);
    match sc.run().unwrap() {
        ScenarioOutcome::Single { outputs, .. } => assert!(outputs.completed),
        _ => panic!("expected Single outcome"),
    }
}

#[test]
fn tune_renders_in_all_four_formats() {
    let sc = Scenario::from_yaml(&rigged_tune_yaml()).unwrap();
    let outcome = sc.run().unwrap();
    let record = sc.record(&outcome);

    let txt = Format::Text.sink().scenario(&record);
    assert!(txt.contains("search trail"), "{txt}");
    assert!(txt.contains("winner:"), "{txt}");

    let doc = parse_json(Format::Json.sink().scenario(&record).trim_end()).unwrap();
    let result = obj_get(&doc, "result").unwrap();
    let Some(Json::Arr(trail)) = obj_get(result, "trail") else { panic!() };
    assert_eq!(trail.len(), 4);
    let best = obj_get(result, "best").unwrap();
    let Some(Json::Str(yaml)) = obj_get(best, "yaml") else { panic!("best.yaml missing") };
    assert!(yaml.contains("scenario: single"), "{yaml}");

    let csv = Format::Csv.sink().scenario(&record);
    assert_eq!(csv.lines().next().unwrap(), "candidate,n,mean,ci95,pruned_round,winner");
    assert_eq!(csv.trim_end().lines().count(), 1 + 4);

    let nd = Format::Ndjson.sink().scenario(&record);
    let mut counts = std::collections::BTreeMap::new();
    for line in nd.trim_end().lines() {
        let doc = parse_json(line).unwrap_or_else(|e| panic!("bad line `{line}`: {e}"));
        let Some(Json::Str(t)) = obj_get(&doc, "type") else { panic!("untyped line") };
        *counts.entry(t.clone()).or_insert(0usize) += 1;
    }
    assert_eq!(counts.get("optimize"), Some(&1));
    assert_eq!(counts.get("candidate"), Some(&4));
    assert_eq!(counts.get("best"), Some(&1));
}

// ------------------------------------------------------- determinism

/// Satellite bugfix pin: optimize reports are byte-identical across
/// repeated runs and across worker thread counts — ranking and pruning
/// always iterate stable declaration-order structures, never map order.
#[test]
fn optimize_output_byte_identical_across_runs_and_threads() {
    let render = |threads: usize, yaml: &str| {
        let mut sc = Scenario::from_yaml(yaml).unwrap();
        sc.threads = threads;
        let outcome = sc.run().unwrap();
        Format::Text.sink().scenario(&sc.record_owned(outcome))
    };
    let screen = std::fs::read_to_string("configs/scenario_optimize.yaml").unwrap();
    assert_eq!(render(1, &screen), render(1, &screen), "screen: repeated runs");
    assert_eq!(render(1, &screen), render(4, &screen), "screen: thread counts");
    let tune = rigged_tune_yaml();
    assert_eq!(render(1, &tune), render(1, &tune), "tune: repeated runs");
    assert_eq!(render(1, &tune), render(4, &tune), "tune: thread counts");
}

// -------------------------------------------- multi delta-CI columns

/// `scenario: multi` rides the same stats: structured sinks gain
/// `delta_ci`/`significant` on non-baseline rows, while the legacy text
/// table stays byte-free of the new columns unless `show_ci: true`.
#[test]
fn multi_gains_delta_ci_columns_in_structured_formats_only() {
    let yaml = |show_ci: &str| {
        format!(
            "scenario: multi\nseed: 9\nreplications: 4\ncrn: true\nbaseline: slow\n{show_ci}{SMALL}\
             children:\n  - label: slow\n    params: {{ recovery_time: 60 }}\n\
             \x20 - label: fast\n    params: {{ recovery_time: 5 }}\n"
        )
    };
    let sc = Scenario::from_yaml(&yaml("")).unwrap();
    let outcome = sc.run().unwrap();
    let record = sc.record(&outcome);

    // JSON: baseline rows carry no delta_ci; non-baseline rows do.
    let doc = parse_json(Format::Json.sink().scenario(&record).trim_end()).unwrap();
    let result = obj_get(&doc, "result").unwrap();
    let Some(Json::Arr(rows)) = obj_get(result, "comparison") else { panic!() };
    let Some(Json::Arr(children)) = obj_get(&rows[0], "children") else { panic!() };
    assert!(obj_get(&children[0], "delta_ci").is_none(), "baseline has no delta CI");
    assert!(obj_get(&children[1], "delta_ci").is_some(), "non-baseline rows gain delta_ci");
    assert!(obj_get(&children[1], "significant").is_some());

    // CSV: the extended header always present; baseline cells empty.
    let csv = Format::Csv.sink().scenario(&record);
    assert!(csv.starts_with("metric,unit,child,n,mean,std,ci95,delta,delta_pct,delta_ci,significant\n"));

    // Text without `show_ci`: the legacy table, no CI column.
    let txt = Format::Text.sink().scenario(&record);
    assert!(!txt.contains("Δ±95%CI"), "legacy text must not grow columns: {txt}");

    // Text with `show_ci: true`: the CI column and significance marks.
    let sc = Scenario::from_yaml(&yaml("show_ci: true\n")).unwrap();
    let outcome = sc.run().unwrap();
    let txt = Format::Text.sink().scenario(&sc.record_owned(outcome));
    assert!(txt.contains("Δ±95%CI"), "{txt}");
    assert!(txt.contains("sig"), "{txt}");
}
