//! Scenario-layer integration: the shipped scenario files parse and run,
//! and injections target arbitrary jobs with clean drops.

use airesim::config::Params;
use airesim::model::cluster::Simulation;
use airesim::model::events::FailureKind;
use airesim::scenario::{Scenario, ScenarioOutcome};
use airesim::trace::inject::{Injection, InjectionPlan};

fn load(path: &str) -> Scenario {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path}: {e}"));
    Scenario::from_yaml(&text).unwrap_or_else(|e| panic!("{path}: {e}"))
}

#[test]
fn shipped_whatif_scenario_runs() {
    let sc = load("configs/scenario_recovery_whatif.yaml");
    assert_eq!(sc.policies.selection, "locality");
    match sc.run().unwrap() {
        ScenarioOutcome::WhatIf { result, param, factor } => {
            assert_eq!(param, "recovery_time");
            assert_eq!(factor, 2.0);
            assert_eq!(result.points.len(), 2);
            let rendered = sc.render(&ScenarioOutcome::WhatIf { result, param, factor });
            assert!(rendered.contains("selection=locality"), "{rendered}");
            assert!(rendered.contains("scaling recovery_time"), "{rendered}");
        }
        _ => panic!("expected WhatIf outcome"),
    }
}

#[test]
fn shipped_inject_scenario_replays_incident() {
    let sc = load("configs/scenario_incident_replay.yaml");
    match sc.run().unwrap() {
        ScenarioOutcome::Inject { outputs, trace } => {
            // Three scripted failures land on the (only) running job.
            assert_eq!(outputs.failures_total, 3);
            assert!(outputs.completed);
            assert!(!trace.is_empty(), "inject scenarios trace by default");
        }
        _ => panic!("expected Inject outcome"),
    }
}

/// Two quiet jobs; one scripted failure against job 1 only.
fn two_quiet_jobs() -> Params {
    let mut p = Params::small_test();
    p.num_jobs = 2;
    p.job_size = 16;
    p.warm_standbys = 2;
    p.working_pool = 40;
    p.spare_pool = 4;
    p.random_failure_rate = 0.0;
    p.systematic_failure_rate = 0.0;
    p.systematic_fraction = 0.0;
    p.diagnosis_prob = 1.0;
    p.diagnosis_uncertainty = 0.0;
    p.auto_repair_time = 1e7; // failed server does not return in-job
    p.manual_repair_time = 1e7;
    p
}

#[test]
fn injection_targets_the_named_job_only() {
    let p = two_quiet_jobs();
    let plan = InjectionPlan::new(vec![Injection::for_job(
        1,
        100.0,
        0,
        FailureKind::Random,
    )]);
    let out = Simulation::new(&p, 1).with_injections(plan).run();
    assert!(out.completed);
    assert_eq!(out.failures_total, 1);
    // Job 0 never failed: exactly selection + length. Job 1 paid one
    // standby-swap recovery on top.
    let want0 = p.host_selection_time + p.job_len;
    let want1 = p.host_selection_time + p.job_len + p.recovery_time;
    assert!(
        (out.per_job_makespans[0] - want0).abs() < 1e-6,
        "job 0 perturbed: {} vs {want0}",
        out.per_job_makespans[0]
    );
    assert!(
        (out.per_job_makespans[1] - want1).abs() < 1e-6,
        "job 1 missed its injection: {} vs {want1}",
        out.per_job_makespans[1]
    );
}

#[test]
fn injection_against_missing_or_idle_job_drops_cleanly() {
    let p = two_quiet_jobs();
    let plan = InjectionPlan::new(vec![
        // No such job: dropped.
        Injection::for_job(9, 100.0, 0, FailureKind::Random),
        // After both jobs are done: dropped (not running).
        Injection::for_job(0, p.job_len + 1e6, 0, FailureKind::Random),
    ]);
    let out = Simulation::new(&p, 2).with_injections(plan).run();
    assert!(out.completed);
    assert_eq!(out.failures_total, 0, "both injections must drop cleanly");
}
