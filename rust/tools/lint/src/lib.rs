//! airesim-lint: dep-free cross-layer consistency and determinism checks.
//!
//! Four passes (see `rust/README.md` § Static analysis):
//!
//! 1. `registry`   — param / policy / metric / scenario-kind name sets must be
//!    identical across every layer that spells them out by hand.
//! 2. `determinism` — sim-core modules must not use hash-ordered containers,
//!    wall clocks, or lock-ordered float accumulation.
//! 3. `draws`      — every RNG draw site must appear in the committed
//!    allowlist `rust/tools/lint/draw_sites.txt`.
//! 4. `configs`    — every `rust/configs/*.yaml` references only registered
//!    params, policies, metrics, and scenario keys.

use std::path::Path;

pub mod configs;
pub mod determinism;
pub mod draws;
pub mod lexer;
pub mod registry;
pub mod yaml;

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Which pass produced it: `registry`, `determinism`, `draws`, `configs`.
    pub pass: &'static str,
    /// Machine-readable rule id (also the `lint:allow` key where applicable).
    pub rule: String,
    /// Repo-relative file path.
    pub file: String,
    /// 1-based line, or 0 when the finding is about a whole file/set.
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(
        pass: &'static str,
        rule: &str,
        file: impl Into<String>,
        line: usize,
        message: impl Into<String>,
    ) -> Self {
        Finding {
            pass,
            rule: rule.to_string(),
            file: file.into(),
            line,
            message: message.into(),
        }
    }

    pub fn render(&self) -> String {
        if self.line > 0 {
            format!(
                "[{}/{}] {}:{}: {}",
                self.pass, self.rule, self.file, self.line, self.message
            )
        } else {
            format!("[{}/{}] {}: {}", self.pass, self.rule, self.file, self.message)
        }
    }
}

/// Run all four passes rooted at the repo root (the directory containing
/// `rust/src/config/params.rs`). Returns findings; `Err` means the lint
/// itself could not run (missing anchor, unreadable file).
pub fn run_all(root: &Path) -> Result<Vec<Finding>, String> {
    let (regs, mut findings) = registry::check(root)?;
    findings.extend(determinism::check(root)?);
    findings.extend(draws::check(root)?);
    findings.extend(configs::check(root, &regs)?);
    Ok(findings)
}

pub(crate) fn read_rel(root: &Path, rel: &str) -> Result<String, String> {
    std::fs::read_to_string(root.join(rel)).map_err(|e| format!("cannot read {rel}: {e}"))
}

/// Collect `.rs` files under `dir` recursively, in sorted (deterministic) order.
pub(crate) fn walk_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<_> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

/// `path` rendered relative to `root` with forward slashes.
pub(crate) fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}
