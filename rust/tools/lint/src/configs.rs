//! Pass 4 — config lint.
//!
//! Every `rust/configs/*.yaml` must reference only registered names: params
//! from `Params::sweepable_names` (plus `failure_dist`), policies from the
//! `model/policy.rs` registries, scenario kinds from `scenario/mod.rs`,
//! optimize objectives from the metric registry, and only the structural keys
//! each section's parser actually reads. This catches the config that would
//! fail (or worse, silently ignore a knob) at runtime — at lint time.
//!
//! Key sets mirror the strict parsers in `config/validate.rs`,
//! `scenario/mod.rs`, `scenario/study.rs`, `sweep/mod.rs`, `optimize/mod.rs`.

use std::path::Path;

use crate::registry::Registries;
use crate::yaml::{self, Y};
use crate::{rel_path, Finding};

const TOP_KEYS: &[&str] = &[
    "baseline",
    "children",
    "crn",
    "inject",
    "optimize",
    "params",
    "policies",
    "replications",
    "scenario",
    "seed",
    "show_ci",
    "sweep",
    "threads",
    "title",
    "topology",
    "trace",
    "whatif",
    "workload",
];

pub fn check(root: &Path, regs: &Registries) -> Result<Vec<Finding>, String> {
    let dir = root.join("rust/configs");
    let mut paths: Vec<_> = std::fs::read_dir(&dir)
        .map_err(|e| format!("cannot read rust/configs: {e}"))?
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("yaml"))
        .collect();
    paths.sort();
    let mut findings = Vec::new();
    for path in paths {
        let rel = rel_path(root, &path);
        let text =
            std::fs::read_to_string(&path).map_err(|e| format!("cannot read {rel}: {e}"))?;
        findings.extend(check_doc(&rel, &text, regs));
    }
    Ok(findings)
}

/// Lint one config document. `rel` is used only for reporting.
pub fn check_doc(rel: &str, text: &str, regs: &Registries) -> Vec<Finding> {
    let mut f = Vec::new();
    let doc = match yaml::parse(text) {
        Ok(doc) => doc,
        Err(e) => {
            f.push(Finding::new("configs", "yaml-parse", rel, 0, e));
            return f;
        }
    };

    check_keys(&mut f, rel, &doc, "top level", TOP_KEYS);

    if let Some(kind) = doc.get("scenario").and_then(|v| v.as_str()) {
        if !regs.kinds.contains(kind) {
            f.push(bad(rel, "scenario-kind", format!("unknown scenario kind `{kind}`")));
        }
    }
    if let Some(params) = doc.get("params") {
        check_params(&mut f, rel, params, regs, "params");
    }
    if let Some(policies) = doc.get("policies") {
        check_policies(&mut f, rel, policies, regs, "policies");
    }
    if let Some(sweep) = doc.get("sweep") {
        check_keys(&mut f, rel, sweep, "sweep", &["crn", "kind", "x", "y"]);
        if let Some(kind) = sweep.get("kind").and_then(|v| v.as_str()) {
            if kind != "one_way" && kind != "two_way" {
                f.push(bad(rel, "sweep", format!("unknown sweep kind `{kind}`")));
            }
        }
        for key in ["x", "y"] {
            if let Some(axis) = sweep.get(key) {
                check_keys(&mut f, rel, axis, &format!("sweep.{key}"), &["name", "values"]);
                check_knob(
                    &mut f,
                    rel,
                    &format!("sweep.{key}"),
                    axis.get("name").and_then(|v| v.as_str()),
                    axis.get("values"),
                    regs,
                );
            }
        }
    }
    if let Some(whatif) = doc.get("whatif") {
        check_keys(&mut f, rel, whatif, "whatif", &["factor", "param"]);
        if let Some(p) = whatif.get("param").and_then(|v| v.as_str()) {
            if !regs.params.contains(p) {
                f.push(bad(rel, "whatif", format!("unknown param `{p}` in whatif")));
            }
        }
    }
    if let Some(inject) = doc.get("inject") {
        check_keys(&mut f, rel, inject, "inject", &["failures"]);
        for item in inject.get("failures").and_then(|v| v.as_list()).unwrap_or(&[]) {
            check_keys(&mut f, rel, item, "inject failure", &["at", "job", "kind", "victim"]);
            if let Some(k) = item.get("kind").and_then(|v| v.as_str()) {
                if k != "random" && k != "systematic" {
                    f.push(bad(rel, "inject", format!("unknown injection kind `{k}`")));
                }
            }
        }
    }
    if let Some(opt) = doc.get("optimize") {
        check_keys(
            &mut f,
            rel,
            opt,
            "optimize",
            &["budget", "direction", "knobs", "mode", "objective"],
        );
        if let Some(m) = opt.get("mode").and_then(|v| v.as_str()) {
            if m != "screen" && m != "tune" {
                f.push(bad(rel, "optimize", format!("unknown optimize mode `{m}`")));
            }
        }
        if let Some(d) = opt.get("direction").and_then(|v| v.as_str()) {
            if d != "min" && d != "max" {
                f.push(bad(rel, "optimize", format!("unknown direction `{d}`")));
            }
        }
        if let Some(o) = opt.get("objective").and_then(|v| v.as_str()) {
            if !regs.metric_names().contains(o) {
                f.push(bad(rel, "optimize", format!("objective `{o}` is not a metric")));
            }
        }
        for knob in opt.get("knobs").and_then(|v| v.as_list()).unwrap_or(&[]) {
            check_keys(&mut f, rel, knob, "optimize knob", &["param", "values"]);
            check_knob(
                &mut f,
                rel,
                "optimize knob",
                knob.get("param").and_then(|v| v.as_str()),
                knob.get("values"),
                regs,
            );
        }
    }
    if let Some(children) = doc.get("children").and_then(|v| v.as_list()) {
        for child in children {
            check_keys(&mut f, rel, child, "study child", &["label", "params", "policies"]);
            if let Some(params) = child.get("params") {
                check_params(&mut f, rel, params, regs, "child params");
            }
            if let Some(policies) = child.get("policies") {
                check_policies(&mut f, rel, policies, regs, "child policies");
            }
        }
    }
    if let Some(topo) = doc.get("topology") {
        check_keys(
            &mut f,
            rel,
            topo,
            "topology",
            &[
                "levels",
                "rack_outage_rate",
                "racks_per_switch",
                "servers_per_rack",
                "switch_outage_rate",
            ],
        );
        for level in topo.get("levels").and_then(|v| v.as_list()).unwrap_or(&[]) {
            check_keys(&mut f, rel, level, "topology level", &["name", "outage_rate", "size"]);
        }
    }
    if let Some(wl) = doc.get("workload") {
        check_keys(&mut f, rel, wl, "workload", &["classes", "empirical", "poisson", "replay"]);
        if let Some(p) = wl.get("poisson") {
            check_keys(&mut f, rel, p, "workload.poisson", &["rate"]);
        }
        for key in ["empirical", "replay"] {
            if let Some(v) = wl.get(key) {
                check_keys(&mut f, rel, v, &format!("workload.{key}"), &["file"]);
            }
        }
        for class in wl.get("classes").and_then(|v| v.as_list()).unwrap_or(&[]) {
            check_keys(
                &mut f,
                rel,
                class,
                "workload class",
                &["job_len", "job_size", "warm_standbys", "weight"],
            );
        }
    }
    f
}

fn bad(rel: &str, rule: &'static str, msg: String) -> Finding {
    Finding::new("configs", rule, rel, 0, msg)
}

fn check_keys(f: &mut Vec<Finding>, rel: &str, v: &Y, what: &str, known: &[&str]) {
    for key in v.keys() {
        if !known.contains(&key) {
            f.push(Finding::new(
                "configs",
                "unknown-key",
                rel,
                0,
                format!("unknown {what} key `{key}` (expected one of: {})", known.join(", ")),
            ));
        }
    }
}

fn check_params(f: &mut Vec<Finding>, rel: &str, params: &Y, regs: &Registries, what: &str) {
    for key in params.keys() {
        if key != "failure_dist" && !regs.params.contains(key) {
            f.push(Finding::new(
                "configs",
                "unknown-param",
                rel,
                0,
                format!("unknown param `{key}` in {what}"),
            ));
        }
    }
}

fn check_policies(f: &mut Vec<Finding>, rel: &str, policies: &Y, regs: &Registries, what: &str) {
    for axis in policies.keys() {
        match regs.axis(axis) {
            None => f.push(Finding::new(
                "configs",
                "unknown-policy",
                rel,
                0,
                format!("unknown policy axis `{axis}` in {what}"),
            )),
            Some(names) => {
                if let Some(v) = policies.get(axis).and_then(|v| v.as_str()) {
                    if !names.contains(v) {
                        f.push(Finding::new(
                            "configs",
                            "unknown-policy",
                            rel,
                            0,
                            format!("unknown `{axis}` policy `{v}` in {what}"),
                        ));
                    }
                }
            }
        }
    }
}

/// A sweep axis or optimize knob: numeric param, or `policies.<axis>` with
/// every value a registered policy name.
fn check_knob(
    f: &mut Vec<Finding>,
    rel: &str,
    what: &str,
    name: Option<&str>,
    values: Option<&Y>,
    regs: &Registries,
) {
    let Some(name) = name else {
        return;
    };
    if let Some(axis) = name.strip_prefix("policies.") {
        match regs.axis(axis) {
            None => f.push(bad(rel, "unknown-policy", format!("unknown policy axis `{name}` in {what}"))),
            Some(names) => {
                for v in values.and_then(|v| v.as_list()).unwrap_or(&[]) {
                    if let Some(s) = v.as_str() {
                        if !names.contains(s) {
                            f.push(bad(
                                rel,
                                "unknown-policy",
                                format!("unknown `{axis}` policy `{s}` in {what}"),
                            ));
                        }
                    }
                }
            }
        }
    } else if !regs.params.contains(name) {
        f.push(bad(rel, "unknown-param", format!("unknown param `{name}` in {what}")));
    }
}
