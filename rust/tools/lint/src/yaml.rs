//! A tiny YAML-subset reader for pass 4 — just enough to walk the maps,
//! lists, and scalars that `rust/configs/*.yaml` actually use (the same
//! subset `airesim`'s own `config::yaml` accepts): indentation-nested maps,
//! `- ` block lists (including list items that open a block map), inline
//! `{k: v, ...}` maps, inline `[a, b]` lists, and `#` comments. Scalars are
//! kept as raw strings — the lint only ever compares names, never numbers.

/// Parsed YAML value. Scalars stay strings.
#[derive(Debug, Clone, PartialEq)]
pub enum Y {
    Str(String),
    List(Vec<Y>),
    Map(Vec<(String, Y)>),
}

impl Y {
    pub fn get(&self, key: &str) -> Option<&Y> {
        match self {
            Y::Map(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Y::Map(entries) => entries.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Y::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Y]> {
        match self {
            Y::List(items) => Some(items),
            _ => None,
        }
    }
}

/// `(indent, content, 1-based line)` for each non-blank line, comments gone.
type Line = (usize, String, usize);

fn strip_comment(line: &str) -> &str {
    let b = line.as_bytes();
    for (i, &c) in b.iter().enumerate() {
        if c == b'#' && (i == 0 || b[i - 1].is_ascii_whitespace()) {
            return &line[..i];
        }
    }
    line
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    for q in ['"', '\''] {
        if s.len() >= 2 && s.starts_with(q) && s.ends_with(q) {
            return s[1..s.len() - 1].to_string();
        }
    }
    s.to_string()
}

/// Position of the first `:` outside brackets that ends the line or is
/// followed by whitespace — i.e. this text opens a map entry.
fn entry_colon(s: &str) -> Option<usize> {
    let b = s.as_bytes();
    let mut depth = 0usize;
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'[' | b'{' => depth += 1,
            b']' | b'}' => depth = depth.saturating_sub(1),
            b':' if depth == 0 => {
                if i + 1 == b.len() || b[i + 1].is_ascii_whitespace() {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Split inline text on top-level commas.
fn split_commas(s: &str) -> Vec<&str> {
    let b = s.as_bytes();
    let mut depth = 0usize;
    let mut start = 0;
    let mut out = Vec::new();
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'[' | b'{' => depth += 1,
            b']' | b'}' => depth = depth.saturating_sub(1),
            b',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn parse_inline(s: &str, ln: usize) -> Result<Y, String> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('{') {
        let inner = inner
            .strip_suffix('}')
            .ok_or_else(|| format!("line {ln}: unterminated inline map"))?;
        let mut entries = Vec::new();
        for part in split_commas(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let c = entry_colon(part)
                .ok_or_else(|| format!("line {ln}: inline map entry `{part}` has no `:`"))?;
            entries.push((unquote(&part[..c]), parse_inline(&part[c + 1..], ln)?));
        }
        return Ok(Y::Map(entries));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("line {ln}: unterminated inline list"))?;
        let mut items = Vec::new();
        for part in split_commas(inner) {
            if !part.trim().is_empty() {
                items.push(parse_inline(part, ln)?);
            }
        }
        return Ok(Y::List(items));
    }
    Ok(Y::Str(unquote(s)))
}

struct Parser<'a> {
    lines: &'a [Line],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<&'a Line> {
        self.lines.get(self.pos)
    }

    /// Parse the block starting at the current line, which has `indent`.
    fn block(&mut self, indent: usize) -> Result<Y, String> {
        match self.peek() {
            Some((_, content, _)) if content == "-" || content.starts_with("- ") => {
                self.list(indent)
            }
            _ => self.map(indent, None),
        }
    }

    fn list(&mut self, indent: usize) -> Result<Y, String> {
        let mut items = Vec::new();
        while let Some(&(ind, ref content, ln)) = self.peek() {
            if ind != indent || !(content == "-" || content.starts_with("- ")) {
                break;
            }
            let rest = content[1..].trim_start().to_string();
            // Column where the item's own content begins.
            let item_indent = ind + (content.len() - rest.len());
            self.pos += 1;
            if rest.is_empty() {
                match self.peek() {
                    Some(&(next_ind, _, _)) if next_ind > indent => {
                        items.push(self.block(next_ind)?);
                    }
                    _ => items.push(Y::Str(String::new())),
                }
            } else if !rest.starts_with('{') && !rest.starts_with('[') && entry_colon(&rest).is_some() {
                // `- key: ...` opens a block map inlined after the dash.
                items.push(self.map(item_indent, Some((rest, ln)))?);
            } else {
                items.push(parse_inline(&rest, ln)?);
            }
        }
        Ok(Y::List(items))
    }

    fn map(&mut self, indent: usize, first: Option<(String, usize)>) -> Result<Y, String> {
        let mut entries = Vec::new();
        if let Some((content, ln)) = first {
            self.entry(&content, ln, indent, &mut entries)?;
        }
        while let Some(&(ind, ref content, ln)) = self.peek() {
            if ind != indent || content == "-" || content.starts_with("- ") {
                break;
            }
            let content = content.clone();
            self.pos += 1;
            self.entry(&content, ln, indent, &mut entries)?;
        }
        Ok(Y::Map(entries))
    }

    fn entry(
        &mut self,
        content: &str,
        ln: usize,
        indent: usize,
        entries: &mut Vec<(String, Y)>,
    ) -> Result<(), String> {
        let c = entry_colon(content)
            .ok_or_else(|| format!("line {ln}: expected `key:`, got `{content}`"))?;
        let key = unquote(&content[..c]);
        let rest = content[c + 1..].trim();
        if rest.is_empty() {
            match self.peek() {
                Some(&(next_ind, _, _)) if next_ind > indent => {
                    let v = self.block(next_ind)?;
                    entries.push((key, v));
                }
                // YAML allows a block list at the same indent as its key.
                Some(&(next_ind, ref c, _))
                    if next_ind == indent && (c == "-" || c.starts_with("- ")) =>
                {
                    let v = self.list(next_ind)?;
                    entries.push((key, v));
                }
                _ => entries.push((key, Y::Str(String::new()))),
            }
        } else {
            entries.push((key, parse_inline(rest, ln)?));
        }
        Ok(())
    }
}

pub fn parse(text: &str) -> Result<Y, String> {
    let mut lines: Vec<Line> = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let no_comment = strip_comment(raw);
        let trimmed = no_comment.trim_end();
        let content = trimmed.trim_start();
        if content.is_empty() || content == "---" {
            continue;
        }
        let indent = trimmed.len() - content.len();
        lines.push((indent, content.to_string(), i + 1));
    }
    if lines.is_empty() {
        return Ok(Y::Map(Vec::new()));
    }
    let indent = lines[0].0;
    let mut p = Parser {
        lines: &lines,
        pos: 0,
    };
    let doc = p.block(indent)?;
    if let Some((_, content, ln)) = p.peek() {
        return Err(format!("line {ln}: unexpected dedent/content `{content}`"));
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_maps_lists_and_inline_forms() {
        let doc = parse(
            "title: demo # comment\nparams:\n  num_jobs: 8\n  rate: 0.5/1440\npolicies: { selection: locality }\nsweep:\n  x:\n    name: job_size\n    values: [64, 128]\nchildren:\n  - label: a\n    params:\n      num_jobs: 4\n  - label: b\n",
        )
        .unwrap();
        assert_eq!(
            doc.get("params").unwrap().get("num_jobs").unwrap().as_str(),
            Some("8")
        );
        assert_eq!(
            doc.get("policies").unwrap().get("selection").unwrap().as_str(),
            Some("locality")
        );
        let x = doc.get("sweep").unwrap().get("x").unwrap();
        assert_eq!(x.get("name").unwrap().as_str(), Some("job_size"));
        assert_eq!(x.get("values").unwrap().as_list().unwrap().len(), 2);
        let kids = doc.get("children").unwrap().as_list().unwrap();
        assert_eq!(kids.len(), 2);
        assert_eq!(kids[0].get("label").unwrap().as_str(), Some("a"));
        assert_eq!(
            kids[0].get("params").unwrap().get("num_jobs").unwrap().as_str(),
            Some("4")
        );
        assert_eq!(kids[1].get("label").unwrap().as_str(), Some("b"));
    }

    #[test]
    fn inline_map_list_items() {
        let doc = parse("inject:\n  failures:\n    - {at: 100, job: 1, kind: random}\n").unwrap();
        let fails = doc
            .get("inject")
            .unwrap()
            .get("failures")
            .unwrap()
            .as_list()
            .unwrap();
        assert_eq!(fails[0].get("kind").unwrap().as_str(), Some("random"));
    }
}
