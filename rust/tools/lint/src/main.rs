//! `airesim-lint` CLI: run all four passes over the repo and report findings.
//!
//!     cargo run -p airesim-lint            # human-readable, exit 1 on findings
//!     cargo run -p airesim-lint -- --json  # machine-readable findings array
//!
//! The repo root is discovered by walking up from the current directory until
//! `rust/src/config/params.rs` is found, or pass `--root <dir>` explicitly.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: airesim-lint [--json] [--root <repo-root>]";

fn discover_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src/config/params.rs").is_file() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(dir) => root = Some(PathBuf::from(dir)),
                None => {
                    eprintln!("{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("airesim-lint: unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    let Some(root) = root.or_else(discover_root) else {
        eprintln!("airesim-lint: cannot find repo root (looked for rust/src/config/params.rs)");
        return ExitCode::from(2);
    };

    match airesim_lint::run_all(&root) {
        Err(e) => {
            eprintln!("airesim-lint: fatal: {e}");
            ExitCode::from(2)
        }
        Ok(findings) => {
            if json {
                let items: Vec<String> = findings
                    .iter()
                    .map(|f| {
                        format!(
                            "{{\"pass\":\"{}\",\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                            f.pass,
                            json_escape(&f.rule),
                            json_escape(&f.file),
                            f.line,
                            json_escape(&f.message)
                        )
                    })
                    .collect();
                println!("[{}]", items.join(","));
            } else if findings.is_empty() {
                println!("airesim-lint: clean (registry, determinism, draws, configs)");
            } else {
                for f in &findings {
                    println!("{}", f.render());
                }
                println!("airesim-lint: {} finding(s)", findings.len());
            }
            if findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
    }
}
