//! Pass 2 — determinism.
//!
//! AIReSim's paired-CRN comparisons are only valid if a given seed produces a
//! byte-identical event stream on every run. Three lexical hazards can break
//! that silently in sim-core code (`model/`, `sim/`, `scenario/`, `sweep/`,
//! `optimize/`, `serve/`):
//!
//! * `hash-container` — `HashMap`/`HashSet` iterate in randomized hash order.
//!   Use `BTreeMap`/`BTreeSet`, or annotate when the container is only ever
//!   used for keyed lookup (never iterated into an order-sensitive result).
//! * `wall-clock` — `Instant`/`SystemTime` import real time into a simulated
//!   timeline.
//! * `float-accum` — in a module that shares state through locks, `+=` with a
//!   non-integer right-hand side accumulates in completion order; integer
//!   counters are exact in any order, float sums are not. Sort samples before
//!   reducing (see `sweep::run_pool`) or annotate.
//!
//! Audited exceptions carry `// lint:allow(<rule>) <reason>` on (or directly
//! above) the offending line; an annotation without a reason is itself a
//! finding. Test code (`#[cfg(test)]` blocks) is skipped.

use std::path::Path;

use crate::lexer;
use crate::{rel_path, walk_rs, Finding};

/// Directories under `rust/src/` held to the determinism rules.
pub const SIM_CORE_DIRS: &[&str] = &["model", "sim", "scenario", "sweep", "optimize", "serve"];

pub fn check(root: &Path) -> Result<Vec<Finding>, String> {
    let mut findings = Vec::new();
    for dir in SIM_CORE_DIRS {
        let mut files = Vec::new();
        walk_rs(&root.join("rust/src").join(dir), &mut files);
        for path in files {
            let rel = rel_path(root, &path);
            let src = std::fs::read_to_string(&path)
                .map_err(|e| format!("cannot read {rel}: {e}"))?;
            findings.extend(scan_file(&rel, &src));
        }
    }
    Ok(findings)
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// `word` occurs in `line` with non-identifier characters on both sides.
fn has_word(line: &str, word: &str) -> bool {
    let b = line.as_bytes();
    let mut from = 0;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident(b[at - 1]);
        let after_ok = end >= b.len() || !is_ident(b[end]);
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

/// RHS of the first `+=` on the line is a bare integer literal (`1`, `2_000`).
fn int_rhs(line: &str) -> bool {
    let Some(p) = line.find("+=") else {
        return true;
    };
    let rhs = line[p + 2..].trim().trim_end_matches(';').trim();
    !rhs.is_empty() && rhs.bytes().all(|c| c.is_ascii_digit() || c == b'_')
}

/// Scan one file's source. `rel` is used only for reporting.
pub fn scan_file(rel: &str, src: &str) -> Vec<Finding> {
    let s = lexer::scan(src);
    let mut out = Vec::new();

    for a in &s.allows {
        if !a.has_reason {
            out.push(Finding::new(
                "determinism",
                "allow-reason",
                rel,
                a.line,
                format!(
                    "`lint:allow({})` without a reason — say why the exception is sound",
                    a.rule
                ),
            ));
        }
    }

    let locks = (1..=s.num_lines())
        .any(|n| !s.in_tests(n) && s.code_line(n).contains(".lock("));

    for n in 1..=s.num_lines() {
        if s.in_tests(n) {
            continue;
        }
        let line = s.code_line(n);
        if (has_word(line, "HashMap") || has_word(line, "HashSet"))
            && !s.is_allowed(n, "hash-container")
        {
            out.push(Finding::new(
                "determinism",
                "hash-container",
                rel,
                n,
                "hash-ordered container in sim-core; use BTreeMap/BTreeSet or \
                 `lint:allow(hash-container)` with the audit reason",
            ));
        }
        if (has_word(line, "Instant") || has_word(line, "SystemTime"))
            && !s.is_allowed(n, "wall-clock")
        {
            out.push(Finding::new(
                "determinism",
                "wall-clock",
                rel,
                n,
                "wall-clock time in sim-core; simulated time only, or \
                 `lint:allow(wall-clock)` with the audit reason",
            ));
        }
        if locks && line.contains("+=") && !int_rhs(line) && !s.is_allowed(n, "float-accum") {
            out.push(Finding::new(
                "determinism",
                "float-accum",
                rel,
                n,
                "non-integer `+=` in a lock-sharing module accumulates in \
                 completion order; sort before reducing (see sweep::run_pool) \
                 or `lint:allow(float-accum)` with the audit reason",
            ));
        }
    }
    out
}
