//! Pass 3 — RNG draw-site discipline.
//!
//! CRN pairing and byte-identity depend on every replication consuming the
//! exact same draw sequence. A new `rng.` draw site anywhere in sim code can
//! shift every subsequent draw and silently invalidate paired comparisons, so
//! each draw site must be accounted for in the committed allowlist
//! `rust/tools/lint/draw_sites.txt` (`<file> <method> <count>` per line,
//! paths relative to `rust/src/`). The lint fails on *both* new sites and
//! stale entries: adding a draw requires a human to re-audit stream
//! discipline (derived streams, draw order) and bump the allowlist in the
//! same commit.
//!
//! `sim/rng.rs` (the generator itself) and `testkit/` are exempt, as is all
//! `#[cfg(test)]` code.

use std::collections::BTreeMap;
use std::path::Path;

use crate::lexer;
use crate::{read_rel, rel_path, walk_rs, Finding};

/// Methods on `sim::rng::Rng` (plus `Dist::sample`) that consume randomness.
pub const DRAW_METHODS: &[&str] = &[
    "bernoulli",
    "next_below",
    "next_f64",
    "next_normal",
    "next_open_f64",
    "next_u64",
    "sample",
    "shuffle",
];

pub const ALLOWLIST: &str = "rust/tools/lint/draw_sites.txt";

/// Count non-test draw sites per method in one file.
pub fn count_draws(src: &str) -> BTreeMap<String, usize> {
    let s = lexer::scan(src);
    let mut out = BTreeMap::new();
    for n in 1..=s.num_lines() {
        if s.in_tests(n) {
            continue;
        }
        let line = s.code_line(n);
        for m in DRAW_METHODS {
            let hits = line.matches(&format!(".{m}(")).count();
            if hits > 0 {
                *out.entry(m.to_string()).or_insert(0) += hits;
            }
        }
    }
    out
}

pub fn parse_allowlist(text: &str) -> Result<BTreeMap<(String, String), usize>, String> {
    let mut out = BTreeMap::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(file), Some(method), Some(count), None) =
            (parts.next(), parts.next(), parts.next(), parts.next())
        else {
            return Err(format!(
                "{ALLOWLIST}:{}: expected `<file> <method> <count>`",
                i + 1
            ));
        };
        let count: usize = count
            .parse()
            .map_err(|_| format!("{ALLOWLIST}:{}: bad count `{count}`", i + 1))?;
        out.insert((file.to_string(), method.to_string()), count);
    }
    Ok(out)
}

/// Compare found draw sites against the allowlist; both directions fail.
pub fn diff(
    found: &BTreeMap<(String, String), usize>,
    allowed: &BTreeMap<(String, String), usize>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    for ((file, method), n) in found {
        match allowed.get(&(file.clone(), method.clone())) {
            Some(a) if a == n => {}
            Some(a) => out.push(Finding::new(
                "draws",
                "draw-site",
                format!("rust/src/{file}"),
                0,
                format!(
                    "{n} `.{method}(` draw site(s), allowlist says {a} — confirm CRN \
                     stream discipline is preserved, then update {ALLOWLIST}"
                ),
            )),
            None => out.push(Finding::new(
                "draws",
                "draw-site",
                format!("rust/src/{file}"),
                0,
                format!(
                    "new draw site: {n} `.{method}(` call(s) not in {ALLOWLIST} — \
                     confirm CRN stream discipline, then add `{file} {method} {n}`"
                ),
            )),
        }
    }
    for ((file, method), a) in allowed {
        if !found.contains_key(&(file.clone(), method.clone())) {
            out.push(Finding::new(
                "draws",
                "draw-site",
                ALLOWLIST,
                0,
                format!("stale entry `{file} {method} {a}`: no such draw site remains"),
            ));
        }
    }
    out
}

pub fn check(root: &Path) -> Result<Vec<Finding>, String> {
    let allowed = parse_allowlist(&read_rel(root, ALLOWLIST)?)?;
    let mut files = Vec::new();
    walk_rs(&root.join("rust/src"), &mut files);
    let mut found: BTreeMap<(String, String), usize> = BTreeMap::new();
    for path in files {
        let rel = rel_path(root, &path);
        let short = rel.strip_prefix("rust/src/").unwrap_or(&rel).to_string();
        if short == "sim/rng.rs" || short.starts_with("testkit/") {
            continue;
        }
        let src =
            std::fs::read_to_string(&path).map_err(|e| format!("cannot read {rel}: {e}"))?;
        for (method, n) in count_draws(&src) {
            *found.entry((short.clone(), method)).or_insert(0) += n;
        }
    }
    Ok(diff(&found, &allowed))
}
