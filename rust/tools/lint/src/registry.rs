//! Pass 1 — registry drift.
//!
//! Every knob/policy/metric/scenario-kind name is spelled out by hand in
//! several layers (params set/get/sweepable, validate, `model/policy.rs`
//! consts + builder matches + module doc, `stats/metrics.rs` registry, README
//! tables). The compiler cannot tell when one copy drifts; this pass extracts
//! each name set lexically and asserts they are identical.
//!
//! Extraction never interprets Rust — it slices a function/const body by
//! brace matching over the comment/string-blanked `code` view, then collects
//! the string literals inside, optionally restricted to match-arm *patterns*
//! (literal followed by `=>` or `|`) or match-arm *values* (literal preceded
//! by `=>`).

use std::collections::BTreeSet;
use std::path::Path;

use crate::lexer::{self, Lit, Scanned};
use crate::{read_rel, Finding};

const PARAMS_RS: &str = "rust/src/config/params.rs";
const VALIDATE_RS: &str = "rust/src/config/validate.rs";
const POLICY_RS: &str = "rust/src/model/policy.rs";
const METRICS_RS: &str = "rust/src/stats/metrics.rs";
const SCENARIO_RS: &str = "rust/src/scenario/mod.rs";
const README_MD: &str = "rust/README.md";

/// The authoritative name sets, shared with pass 4 (config lint).
pub struct Registries {
    /// Sweepable param names (`Params::sweepable_names`).
    pub params: BTreeSet<String>,
    /// Policy axis -> registered policy names (`*_NAMES` consts).
    pub axes: Vec<(String, BTreeSet<String>)>,
    /// `(name, unit)` in registry (presentation) order.
    pub metrics: Vec<(String, String)>,
    /// Scenario kinds (`fn kind_name`).
    pub kinds: BTreeSet<String>,
}

impl Registries {
    pub fn axis(&self, name: &str) -> Option<&BTreeSet<String>> {
        self.axes.iter().find(|(a, _)| a == name).map(|(_, s)| s)
    }

    pub fn metric_names(&self) -> BTreeSet<String> {
        self.metrics.iter().map(|(n, _)| n.clone()).collect()
    }
}

// ---------------------------------------------------------------- slicing

fn anchor_pos(s: &Scanned, anchor: &str, file: &str) -> Result<usize, String> {
    s.code
        .find(anchor)
        .ok_or_else(|| format!("{file}: anchor `{anchor}` not found (lint needs updating?)"))
}

fn delim_block(
    s: &Scanned,
    from: usize,
    open: u8,
    close: u8,
    file: &str,
    anchor: &str,
) -> Result<(usize, usize), String> {
    let cb = s.code.as_bytes();
    let mut i = from;
    while i < cb.len() && cb[i] != open {
        i += 1;
    }
    if i >= cb.len() {
        return Err(format!("{file}: no opening delimiter after `{anchor}`"));
    }
    let start = i + 1;
    let mut depth = 1usize;
    i += 1;
    while i < cb.len() {
        if cb[i] == open {
            depth += 1;
        } else if cb[i] == close {
            depth -= 1;
            if depth == 0 {
                return Ok((start, i));
            }
        }
        i += 1;
    }
    Err(format!("{file}: unbalanced delimiters after `{anchor}`"))
}

/// Body of the fn/match introduced by `anchor` (first `{...}` after it).
fn fn_block(s: &Scanned, anchor: &str, file: &str) -> Result<(usize, usize), String> {
    let at = anchor_pos(s, anchor, file)?;
    delim_block(s, at + anchor.len(), b'{', b'}', file, anchor)
}

/// Body of the `&[...]` array initializer of the const named by `anchor`
/// (first `[...]` after the `=`, skipping the `[` in the type).
fn array_block(s: &Scanned, anchor: &str, file: &str) -> Result<(usize, usize), String> {
    let at = anchor_pos(s, anchor, file)?;
    let cb = s.code.as_bytes();
    let mut i = at + anchor.len();
    while i < cb.len() && cb[i] != b'=' {
        i += 1;
    }
    delim_block(s, i, b'[', b']', file, anchor)
}

// ------------------------------------------------------------- literals

fn lits_in<'a>(s: &'a Scanned, range: (usize, usize)) -> impl Iterator<Item = &'a Lit> {
    s.lits
        .iter()
        .filter(move |l| l.offset >= range.0 && l.offset < range.1)
}

/// Byte offset just past the closing quote (interior is blanked, so the next
/// `"` after the opening quote is always the closing one).
fn lit_end(s: &Scanned, lit: &Lit) -> usize {
    let cb = s.code.as_bytes();
    let mut j = lit.offset + 1;
    while j < cb.len() && cb[j] != b'"' {
        j += 1;
    }
    (j + 1).min(cb.len())
}

/// Literal is a match-arm pattern: next token is `=>` or a single `|`.
fn is_arm_pattern(s: &Scanned, lit: &Lit) -> bool {
    let cb = s.code.as_bytes();
    let mut j = lit_end(s, lit);
    while j < cb.len() && cb[j].is_ascii_whitespace() {
        j += 1;
    }
    if cb[j..].starts_with(b"=>") {
        return true;
    }
    cb.get(j) == Some(&b'|') && cb.get(j + 1) != Some(&b'|')
}

/// Literal is a match-arm value: previous token is `=>`.
fn is_arm_value(s: &Scanned, lit: &Lit) -> bool {
    let cb = s.code.as_bytes();
    let mut j = lit.offset;
    while j > 0 && cb[j - 1].is_ascii_whitespace() {
        j -= 1;
    }
    j >= 2 && &cb[j - 2..j] == b"=>"
}

pub fn is_snake(name: &str) -> bool {
    let b = name.as_bytes();
    !b.is_empty()
        && b[0].is_ascii_lowercase()
        && b.iter()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == b'_')
}

fn arm_lits(s: &Scanned, range: (usize, usize)) -> BTreeSet<String> {
    lits_in(s, range)
        .filter(|l| is_arm_pattern(s, l) && is_snake(&l.text))
        .map(|l| l.text.clone())
        .collect()
}

fn value_lits(s: &Scanned, range: (usize, usize)) -> BTreeSet<String> {
    lits_in(s, range)
        .filter(|l| is_arm_value(s, l) && is_snake(&l.text))
        .map(|l| l.text.clone())
        .collect()
}

fn all_lits(s: &Scanned, range: (usize, usize)) -> BTreeSet<String> {
    lits_in(s, range).map(|l| l.text.clone()).collect()
}

/// Struct field the literal initializes (`name:`, `unit:`, ...), if any.
fn field_of(s: &Scanned, lit: &Lit) -> Option<String> {
    let cb = s.code.as_bytes();
    let mut j = lit.offset;
    while j > 0 && cb[j - 1].is_ascii_whitespace() {
        j -= 1;
    }
    if j == 0 || cb[j - 1] != b':' {
        return None;
    }
    let end = j - 1;
    let mut k = end;
    while k > 0 && (cb[k - 1].is_ascii_alphanumeric() || cb[k - 1] == b'_') {
        k -= 1;
    }
    (k < end).then(|| s.code[k..end].to_string())
}

// ------------------------------------------------------------- reporting

fn assert_same(
    findings: &mut Vec<Finding>,
    rule: &str,
    file: &str,
    line: usize,
    reference: (&str, &BTreeSet<String>),
    other: (&str, &BTreeSet<String>),
) {
    let missing: Vec<&String> = reference.1.difference(other.1).collect();
    let extra: Vec<&String> = other.1.difference(reference.1).collect();
    if missing.is_empty() && extra.is_empty() {
        return;
    }
    let mut parts = Vec::new();
    if !missing.is_empty() {
        parts.push(format!(
            "in {} but missing from {}: {}",
            reference.0,
            other.0,
            missing
                .iter()
                .map(|s| s.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        ));
    }
    if !extra.is_empty() {
        parts.push(format!(
            "in {} but not in {}: {}",
            other.0,
            reference.0,
            extra.iter().map(|s| s.as_str()).collect::<Vec<_>>().join(", ")
        ));
    }
    findings.push(Finding::new("registry", rule, file, line, parts.join("; ")));
}

// ---------------------------------------------------------- README tables

/// Rows of the lint-marked table that follows `<!-- airesim-lint:<tag> -->`:
/// `(marker_line, [(row_line, backtick spans)])`. Header and separator rows
/// carry no backticks and are skipped.
pub fn md_table(readme: &str, tag: &str) -> Option<(usize, Vec<(usize, Vec<String>)>)> {
    let marker = format!("<!-- airesim-lint:{tag} -->");
    let mut rows = Vec::new();
    let mut marker_line = None;
    for (i, line) in readme.lines().enumerate() {
        let t = line.trim();
        if marker_line.is_none() {
            if t == marker {
                marker_line = Some(i + 1);
            }
            continue;
        }
        if t == "<!-- airesim-lint:end -->" {
            break;
        }
        if t.starts_with('|') {
            let spans: Vec<String> = t
                .split('`')
                .enumerate()
                .filter(|(k, _)| k % 2 == 1)
                .map(|(_, v)| v.to_string())
                .collect();
            if !spans.is_empty() {
                rows.push((i + 1, spans));
            }
        }
    }
    marker_line.map(|l| (l, rows))
}

// ----------------------------------------------------------------- check

pub fn check(root: &Path) -> Result<(Registries, Vec<Finding>), String> {
    let mut f = Vec::new();

    // --- params: set_by_name == get_by_name == sweepable_names == validate.
    let ps = lexer::scan(&read_rel(root, PARAMS_RS)?);
    let set_names = arm_lits(&ps, fn_block(&ps, "fn set_by_name(", PARAMS_RS)?);
    let get_names = arm_lits(&ps, fn_block(&ps, "fn get_by_name(", PARAMS_RS)?);
    let sweep_names = all_lits(&ps, fn_block(&ps, "fn sweepable_names(", PARAMS_RS)?);
    assert_same(
        &mut f,
        "param-drift",
        PARAMS_RS,
        0,
        ("sweepable_names", &sweep_names),
        ("set_by_name", &set_names),
    );
    assert_same(
        &mut f,
        "param-drift",
        PARAMS_RS,
        0,
        ("sweepable_names", &sweep_names),
        ("get_by_name", &get_names),
    );

    let vs = lexer::scan(&read_rel(root, VALIDATE_RS)?);
    let mut val_names: BTreeSet<String> = {
        let body = fn_block(&vs, "fn validate(", VALIDATE_RS)?;
        lits_in(&vs, body)
            .filter(|l| is_snake(&l.text))
            .map(|l| l.text.clone())
            .collect()
    };
    val_names.extend(all_lits(
        &vs,
        array_block(&vs, "const TYPE_ENFORCED_PARAMS", VALIDATE_RS)?,
    ));
    assert_same(
        &mut f,
        "param-drift",
        VALIDATE_RS,
        0,
        ("sweepable_names", &sweep_names),
        ("validate (range checks + TYPE_ENFORCED_PARAMS)", &val_names),
    );

    // --- policies: consts == builder matches == module doc == axis names.
    let pol_src = read_rel(root, POLICY_RS)?;
    let pol = lexer::scan(&pol_src);
    let axis_consts = [
        ("selection", "SELECTION_NAMES"),
        ("repair", "REPAIR_NAMES"),
        ("checkpoint", "CHECKPOINT_NAMES"),
        ("failure", "FAILURE_NAMES"),
    ];
    let mut axes = Vec::new();
    for (axis, konst) in axis_consts {
        let names = all_lits(&pol, array_block(&pol, &format!("const {konst}"), POLICY_RS)?);
        let build_anchor = format!("match self.{axis}.as_str()");
        let built = arm_lits(&pol, fn_block(&pol, &build_anchor, POLICY_RS)?);
        assert_same(
            &mut f,
            "policy-drift",
            POLICY_RS,
            0,
            (konst, &names),
            (&format!("PolicySpec::build `{build_anchor}`"), &built),
        );
        axes.push((axis.to_string(), names));
    }
    let set_axes = arm_lits(&pol, fn_block(&pol, "fn set(", POLICY_RS)?);
    let expect_axes: BTreeSet<String> =
        axis_consts.iter().map(|(a, _)| a.to_string()).collect();
    assert_same(
        &mut f,
        "policy-drift",
        POLICY_RS,
        0,
        ("policy axes", &expect_axes),
        ("PolicySpec::set", &set_axes),
    );
    // Module doc lists: `//!   <axis>: <default>   # name | name | ...`.
    for (i, line) in pol_src.lines().enumerate() {
        let Some(rest) = line.trim_start().strip_prefix("//!") else {
            continue;
        };
        let rest = rest.trim_start();
        for (axis, names) in &axes {
            let Some(tail) = rest.strip_prefix(&format!("{axis}:")) else {
                continue;
            };
            let Some((_, list)) = tail.split_once('#') else {
                continue;
            };
            let doc_names: BTreeSet<String> = list
                .split('|')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            assert_same(
                &mut f,
                "policy-drift",
                POLICY_RS,
                i + 1,
                (&format!("{axis} registry"), names),
                ("module doc list", &doc_names),
            );
        }
    }

    // --- metrics: REGISTRY names/units, DEFAULT_METRIC membership.
    let ms = lexer::scan(&read_rel(root, METRICS_RS)?);
    let reg_block = array_block(&ms, "const REGISTRY", METRICS_RS)?;
    let mut metrics: Vec<(String, String)> = Vec::new();
    for lit in lits_in(&ms, reg_block) {
        match field_of(&ms, lit).as_deref() {
            Some("name") => metrics.push((lit.text.clone(), String::new())),
            Some("unit") => {
                if let Some(last) = metrics.last_mut() {
                    last.1 = lit.text.clone();
                }
            }
            _ => {}
        }
    }
    let metric_names: BTreeSet<String> = metrics.iter().map(|(n, _)| n.clone()).collect();
    if metrics.len() != metric_names.len() {
        f.push(Finding::new(
            "registry",
            "metric-drift",
            METRICS_RS,
            0,
            "duplicate metric name in REGISTRY",
        ));
    }
    {
        let at = anchor_pos(&ms, "const DEFAULT_METRIC", METRICS_RS)?;
        match ms.lits.iter().find(|l| l.offset > at) {
            Some(d) if metric_names.contains(&d.text) => {}
            Some(d) => f.push(Finding::new(
                "registry",
                "metric-drift",
                METRICS_RS,
                d.line,
                format!("DEFAULT_METRIC `{}` is not in REGISTRY", d.text),
            )),
            None => f.push(Finding::new(
                "registry",
                "metric-drift",
                METRICS_RS,
                0,
                "cannot find DEFAULT_METRIC value",
            )),
        }
    }

    // --- scenario kinds: from_doc parse arms == kind_name values.
    let ss = lexer::scan(&read_rel(root, SCENARIO_RS)?);
    let parse_kinds = arm_lits(&ss, fn_block(&ss, "match kind_name", SCENARIO_RS)?);
    let kinds = value_lits(&ss, fn_block(&ss, "fn kind_name(", SCENARIO_RS)?);
    assert_same(
        &mut f,
        "kind-drift",
        SCENARIO_RS,
        0,
        ("kind_name (reporting)", &kinds),
        ("Scenario::from_doc (parsing)", &parse_kinds),
    );

    // --- README lint-marked tables.
    let readme = read_rel(root, README_MD)?;
    match md_table(&readme, "params") {
        None => f.push(Finding::new(
            "registry",
            "readme-table",
            README_MD,
            0,
            "missing `<!-- airesim-lint:params -->` table",
        )),
        Some((line, rows)) => {
            let names: BTreeSet<String> = rows
                .iter()
                .filter_map(|(_, spans)| spans.first().cloned())
                .collect();
            assert_same(
                &mut f,
                "readme-table",
                README_MD,
                line,
                ("sweepable_names", &sweep_names),
                ("README params table", &names),
            );
        }
    }
    match md_table(&readme, "policies") {
        None => f.push(Finding::new(
            "registry",
            "readme-table",
            README_MD,
            0,
            "missing `<!-- airesim-lint:policies -->` table",
        )),
        Some((line, rows)) => {
            let mut seen = BTreeSet::new();
            for (rowline, spans) in &rows {
                let axis = &spans[0];
                seen.insert(axis.clone());
                match axes.iter().find(|(a, _)| a == axis) {
                    None => f.push(Finding::new(
                        "registry",
                        "readme-table",
                        README_MD,
                        *rowline,
                        format!("unknown policy axis `{axis}` in README table"),
                    )),
                    Some((_, names)) => {
                        let row_names: BTreeSet<String> = spans[1..].iter().cloned().collect();
                        assert_same(
                            &mut f,
                            "readme-table",
                            README_MD,
                            *rowline,
                            (&format!("{axis} registry"), names),
                            ("README policies table row", &row_names),
                        );
                    }
                }
            }
            let expect: BTreeSet<String> = axes.iter().map(|(a, _)| a.clone()).collect();
            assert_same(
                &mut f,
                "readme-table",
                README_MD,
                line,
                ("policy axes", &expect),
                ("README policies table", &seen),
            );
        }
    }
    match md_table(&readme, "metrics") {
        None => f.push(Finding::new(
            "registry",
            "readme-table",
            README_MD,
            0,
            "missing `<!-- airesim-lint:metrics -->` table",
        )),
        Some((line, rows)) => {
            let row_pairs: Vec<(String, String)> = rows
                .iter()
                .map(|(_, spans)| {
                    (
                        spans.first().cloned().unwrap_or_default(),
                        spans.get(1).cloned().unwrap_or_default(),
                    )
                })
                .collect();
            if row_pairs != metrics {
                let row_names: BTreeSet<String> =
                    row_pairs.iter().map(|(n, _)| n.clone()).collect();
                assert_same(
                    &mut f,
                    "readme-table",
                    README_MD,
                    line,
                    ("metrics REGISTRY", &metric_names),
                    ("README metrics table", &row_names),
                );
                if row_names == metric_names {
                    f.push(Finding::new(
                        "registry",
                        "readme-table",
                        README_MD,
                        line,
                        "README metrics table must match REGISTRY order and units exactly",
                    ));
                }
            }
        }
    }

    Ok((
        Registries {
            params: sweep_names,
            axes,
            metrics,
            kinds,
        },
        f,
    ))
}
