//! A comment/string-aware scanner for Rust source — the only "parsing" the
//! lint does. No syn, no proc-macro machinery: we blank out comments and
//! string-literal *contents* (preserving byte offsets and newlines) so the
//! passes can run plain substring searches over `code` without tripping on
//! names that merely appear in prose, and we record every string literal with
//! its position so registry extraction can slice function bodies by brace
//! matching and collect the literals inside.
//!
//! The scanner also understands two repo conventions:
//!
//! * `// lint:allow(<rule>) <reason>` — an audited exception. An annotation on
//!   a code line covers that line; an annotation on a comment-only line covers
//!   the next code line.
//! * `#[cfg(test)]` — everything inside the attribute's brace block is marked
//!   as test code, which the determinism and draw-site passes skip.

/// A string literal found in the source: raw contents (escapes untouched),
/// the byte offset of the opening quote, and its 1-based line.
#[derive(Debug, Clone)]
pub struct Lit {
    pub text: String,
    pub offset: usize,
    pub line: usize,
}

/// A `lint:allow` annotation site (before target-line resolution).
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: usize,
    pub rule: String,
    pub has_reason: bool,
}

/// Scanned view of one source file.
pub struct Scanned {
    /// Source with comments and string contents replaced by spaces (newlines
    /// kept), so byte offsets and line numbers match the original file.
    pub code: String,
    /// Every string literal, in source order.
    pub lits: Vec<Lit>,
    /// Raw annotation sites (useful for reason checking).
    pub allows: Vec<Allow>,
    line_start: Vec<usize>,
    /// Per line (1-based, index 0 unused): rules allowed on that line.
    allowed: Vec<Vec<String>>,
    /// Per line (1-based): line is inside a `#[cfg(test)]` block.
    in_tests: Vec<bool>,
}

impl Scanned {
    /// 1-based line number containing byte `offset`.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_start.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    pub fn is_allowed(&self, line: usize, rule: &str) -> bool {
        self.allowed
            .get(line)
            .map(|rules| rules.iter().any(|r| r == rule))
            .unwrap_or(false)
    }

    pub fn in_tests(&self, line: usize) -> bool {
        self.in_tests.get(line).copied().unwrap_or(false)
    }

    pub fn num_lines(&self) -> usize {
        self.line_start.len()
    }

    /// Slice of `code` for 1-based line `n`, without the trailing newline.
    pub fn code_line(&self, n: usize) -> &str {
        let start = self.line_start[n - 1];
        let end = self
            .line_start
            .get(n)
            .map(|e| e - 1)
            .unwrap_or(self.code.len());
        &self.code[start..end.max(start)]
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xe0 {
        2
    } else if b < 0xf0 {
        3
    } else {
        4
    }
}

/// Blank `code[from..to]` with spaces, preserving newlines.
fn blank(code: &mut [u8], from: usize, to: usize) {
    for c in code[from..to].iter_mut() {
        if *c != b'\n' {
            *c = b' ';
        }
    }
}

/// Parse `lint:allow(rule) reason` out of a comment's text, if present.
fn parse_allow(comment: &str) -> Option<(String, bool)> {
    let at = comment.find("lint:allow(")?;
    let rest = &comment[at + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let reason = rest[close + 1..].trim();
    Some((rule, !reason.is_empty()))
}

pub fn scan(src: &str) -> Scanned {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut code = bytes.to_vec();
    let mut lits = Vec::new();
    let mut allows = Vec::new();

    let mut line_start = vec![0usize];
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' && i + 1 < n {
            line_start.push(i + 1);
        }
    }
    let line_of = |offset: usize, starts: &[usize]| -> usize {
        match starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    };

    let mut i = 0;
    while i < n {
        let b = bytes[i];
        // Line comment (covers `//`, `///`, `//!`).
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
            let end = bytes[i..]
                .iter()
                .position(|&c| c == b'\n')
                .map(|p| i + p)
                .unwrap_or(n);
            let text = std::str::from_utf8(&bytes[i..end]).unwrap_or("");
            if let Some((rule, has_reason)) = parse_allow(text) {
                allows.push(Allow {
                    line: line_of(i, &line_start),
                    rule,
                    has_reason,
                });
            }
            blank(&mut code, i, end);
            i = end;
            continue;
        }
        // Block comment, possibly nested.
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
            let start = i;
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if bytes[i] == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if bytes[i] == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut code, start, i);
            continue;
        }
        // Raw string: r"..." / r#"..."# / br"..." (prev byte must not be ident).
        if (b == b'r' || b == b'b') && (i == 0 || !is_ident(bytes[i - 1])) {
            let mut j = i;
            if b == b'b' && j + 1 < n && bytes[j + 1] == b'r' {
                j += 1;
            }
            if bytes[j] == b'r' {
                let mut hashes = 0;
                let mut k = j + 1;
                while k < n && bytes[k] == b'#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && bytes[k] == b'"' {
                    let open = k + 1;
                    let closer: Vec<u8> = std::iter::once(b'"')
                        .chain(std::iter::repeat(b'#').take(hashes))
                        .collect();
                    let mut m = open;
                    while m < n && !bytes[m..].starts_with(&closer) {
                        m += 1;
                    }
                    let close = m.min(n);
                    lits.push(Lit {
                        text: String::from_utf8_lossy(&bytes[open..close]).into_owned(),
                        offset: k,
                        line: line_of(k, &line_start),
                    });
                    blank(&mut code, open, close);
                    i = (close + closer.len()).min(n);
                    continue;
                }
            }
            // `b"..."` byte string falls through to the string arm below;
            // a lone `r`/`b` identifier falls through to the default arm.
            if b == b'b' && i + 1 < n && (bytes[i + 1] == b'"' || bytes[i + 1] == b'\'') {
                i += 1; // let the next iteration handle the quote itself
                continue;
            }
            i += 1;
            continue;
        }
        // Ordinary string literal.
        if b == b'"' {
            let open = i + 1;
            let mut j = open;
            while j < n {
                if bytes[j] == b'\\' {
                    j += 2;
                } else if bytes[j] == b'"' {
                    break;
                } else {
                    j += 1;
                }
            }
            let close = j.min(n);
            lits.push(Lit {
                text: String::from_utf8_lossy(&bytes[open..close]).into_owned(),
                offset: i,
                line: line_of(i, &line_start),
            });
            blank(&mut code, open, close);
            i = (close + 1).min(n);
            continue;
        }
        // Char literal vs lifetime.
        if b == b'\'' {
            if i + 1 < n && bytes[i + 1] == b'\\' {
                // Escaped char literal: scan past the escape to the closing quote.
                let mut j = i + 3;
                while j < n && bytes[j] != b'\'' {
                    j += 1;
                }
                blank(&mut code, i + 1, j.min(n));
                i = (j + 1).min(n);
                continue;
            }
            if i + 1 < n {
                let len = utf8_len(bytes[i + 1]);
                if i + 1 + len < n && bytes[i + 1 + len] == b'\'' {
                    // Plain char literal like 'x' (or '"').
                    blank(&mut code, i + 1, i + 1 + len);
                    i += len + 2;
                    continue;
                }
            }
            // Lifetime — leave as code.
            i += 1;
            continue;
        }
        // Skip identifiers wholesale so `br`/`r` prefixes inside names
        // (e.g. `order`) are never mistaken for raw-string openers.
        if is_ident(b) {
            let mut j = i + 1;
            while j < n && is_ident(bytes[j]) {
                j += 1;
            }
            i = j;
            continue;
        }
        i += 1;
    }

    let code = String::from_utf8(code).expect("blanking preserves UTF-8");

    // Per-line blankness of the *code* view (comment-only lines are blank).
    let num_lines = line_start.len();
    let mut blank_line = vec![true; num_lines + 1];
    for (idx, &start) in line_start.iter().enumerate() {
        let end = line_start.get(idx + 1).copied().unwrap_or(code.len());
        blank_line[idx + 1] = code[start..end].trim().is_empty();
    }

    // Resolve allow targets: comment-only lines cover the next code line.
    let mut allowed = vec![Vec::new(); num_lines + 1];
    for a in &allows {
        let mut target = a.line;
        while target <= num_lines && blank_line[target] {
            target += 1;
        }
        if target <= num_lines {
            allowed[target].push(a.rule.clone());
        }
    }

    // Mark `#[cfg(test)]` brace regions.
    let mut in_tests = vec![false; num_lines + 1];
    let cb = code.as_bytes();
    let mut depth: usize = 0;
    let mut pending = false;
    let mut test_depth: Option<usize> = None;
    let mut k = 0;
    while k < cb.len() {
        if test_depth.is_none() && code[k..].starts_with("#[cfg(test)]") {
            pending = true;
            k += "#[cfg(test)]".len();
            continue;
        }
        match cb[k] {
            b'{' => {
                if pending {
                    test_depth = Some(depth);
                    pending = false;
                }
                depth += 1;
            }
            b'}' => {
                depth = depth.saturating_sub(1);
                if test_depth == Some(depth) {
                    test_depth = None;
                    in_tests[line_of(k, &line_start)] = true;
                }
            }
            b';' if pending && test_depth.is_none() => pending = false,
            _ => {}
        }
        if test_depth.is_some() {
            in_tests[line_of(k, &line_start)] = true;
        }
        k += 1;
    }

    Scanned {
        code,
        lits,
        allows,
        line_start,
        allowed,
        in_tests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked_but_offsets_survive() {
        let src = "let x = \"HashMap\"; // HashMap in a comment\nlet y = 2;\n";
        let s = scan(src);
        assert_eq!(s.code.len(), src.len());
        assert!(!s.code.contains("HashMap"));
        assert_eq!(s.lits.len(), 1);
        assert_eq!(s.lits[0].text, "HashMap");
        assert_eq!(s.lits[0].line, 1);
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let src = "let a = r#\"he \"quoted\" {x}\"#; let c = '\"'; let l: &'static str = \"s\";";
        let s = scan(src);
        assert_eq!(s.lits.len(), 2);
        assert_eq!(s.lits[0].text, "he \"quoted\" {x}");
        assert_eq!(s.lits[1].text, "s");
        assert!(s.code.contains("&'static str"));
    }

    #[test]
    fn allow_on_comment_line_covers_next_code_line() {
        let src = "// lint:allow(hash-container) keyed lookups only\nuse std::collections::HashMap;\nlet x = 1;\n";
        let s = scan(src);
        assert!(s.is_allowed(2, "hash-container"));
        assert!(!s.is_allowed(3, "hash-container"));
        assert!(s.allows[0].has_reason);
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let m = 1; }\n}\nfn after() {}\n";
        let s = scan(src);
        assert!(!s.in_tests(1));
        assert!(s.in_tests(4));
        assert!(!s.in_tests(6));
    }
}
