//! Fixture tests: each pass catches a seeded violation, and the annotated /
//! allowlisted variant passes. Fixtures are tiny fake repo trees under
//! `CARGO_TARGET_TMPDIR` carrying just the files the lint reads; the final
//! test runs the real lint against the real repo and requires it clean.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use airesim_lint::{configs, determinism, draws, registry, run_all, Finding};

static SEQ: AtomicUsize = AtomicUsize::new(0);

struct TempRepo {
    root: PathBuf,
}

impl TempRepo {
    fn new(tag: &str) -> TempRepo {
        let root = Path::new(env!("CARGO_TARGET_TMPDIR")).join(format!(
            "lint-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&root).unwrap();
        TempRepo { root }
    }

    fn write(&self, rel: &str, content: &str) {
        let p = self.root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(p, content).unwrap();
    }
}

impl Drop for TempRepo {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

const PARAMS_RS: &str = r#"
impl Params {
    pub fn set_by_name(&mut self, name: &str, value: f64) -> bool {
        match name {
            "alpha" => self.alpha = value,
            "beta" => self.beta = value,
            _ => return false,
        }
        true
    }

    pub fn get_by_name(&self, name: &str) -> Option<f64> {
        Some(match name {
            "alpha" => self.alpha,
            "beta" => self.beta,
            _ => return None,
        })
    }

    pub fn sweepable_names() -> &'static [&'static str] {
        &["alpha", "beta"]
    }
}
"#;

const VALIDATE_RS: &str = r#"
pub const TYPE_ENFORCED_PARAMS: &[&str] = &["beta"];

pub fn validate(p: &Params) -> Result<(), ConfigError> {
    non_neg("alpha", p.alpha)?;
    Ok(())
}
"#;

const POLICY_RS: &str = r#"//! Policy registry fixture.
//!
//!   selection: one      # one | two
//!   repair: fifo        # fifo
//!   checkpoint: auto    # auto
//!   failure: auto       # auto

pub const SELECTION_NAMES: &[&str] = &["one", "two"];
pub const REPAIR_NAMES: &[&str] = &["fifo"];
pub const CHECKPOINT_NAMES: &[&str] = &["auto"];
pub const FAILURE_NAMES: &[&str] = &["auto"];

impl PolicySpec {
    pub fn set(&mut self, axis: &str, value: &str) -> Result<(), String> {
        match axis {
            "selection" => {}
            "repair" => {}
            "checkpoint" => {}
            "failure" => {}
            _ => return Err(format!("unknown axis {axis} {value}")),
        }
        Ok(())
    }

    pub fn build(&self) {
        match self.selection.as_str() {
            "one" => {}
            "two" => {}
            _ => {}
        }
        match self.repair.as_str() {
            "fifo" => {}
            _ => {}
        }
        match self.checkpoint.as_str() {
            "auto" => {}
            _ => {}
        }
        match self.failure.as_str() {
            "auto" => {}
            _ => {}
        }
    }
}
"#;

const METRICS_RS: &str = r#"
pub const DEFAULT_METRIC: &str = "m_one";

pub const REGISTRY: &[Metric] = &[
    Metric { name: "m_one", unit: "min", doc: "first metric" },
    Metric { name: "m_two", unit: "count", doc: "second metric" },
];
"#;

const SCENARIO_RS: &str = r#"
impl Scenario {
    pub fn from_doc() {
        let kind = match kind_name {
            "single" => 1,
            "sweep" => 2,
            other => 0,
        };
    }
}

fn kind_name(kind: &ScenarioKind) -> &'static str {
    match kind {
        ScenarioKind::Single => "single",
        ScenarioKind::Sweep => "sweep",
    }
}
"#;

const README_MD: &str = r#"# fixture

<!-- airesim-lint:params -->
| parameter | meaning |
|---|---|
| `alpha` | a knob |
| `beta` | another knob |
<!-- airesim-lint:end -->

<!-- airesim-lint:policies -->
| axis | policies |
|---|---|
| `selection` | `one`, `two` |
| `repair` | `fifo` |
| `checkpoint` | `auto` |
| `failure` | `auto` |
<!-- airesim-lint:end -->

<!-- airesim-lint:metrics -->
| metric | unit | meaning |
|---|---|---|
| `m_one` | `min` | first metric |
| `m_two` | `count` | second metric |
<!-- airesim-lint:end -->
"#;

const MODEL_RS: &str = r#"
pub fn roll(rng: &mut Rng) -> bool {
    rng.bernoulli(0.5)
}
"#;

const DRAW_SITES: &str = "model/x.rs bernoulli 1\n";

const OK_YAML: &str = "title: fixture\nparams:\n  alpha: 0.5\npolicies: { selection: one }\n";

fn clean_repo(tag: &str) -> TempRepo {
    let repo = TempRepo::new(tag);
    repo.write("rust/src/config/params.rs", PARAMS_RS);
    repo.write("rust/src/config/validate.rs", VALIDATE_RS);
    repo.write("rust/src/model/policy.rs", POLICY_RS);
    repo.write("rust/src/model/x.rs", MODEL_RS);
    repo.write("rust/src/stats/metrics.rs", METRICS_RS);
    repo.write("rust/src/scenario/mod.rs", SCENARIO_RS);
    repo.write("rust/README.md", README_MD);
    repo.write("rust/tools/lint/draw_sites.txt", DRAW_SITES);
    repo.write("rust/configs/ok.yaml", OK_YAML);
    repo
}

fn rendered(findings: &[Finding]) -> String {
    findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
}

#[test]
fn clean_fixture_repo_lints_clean() {
    let repo = clean_repo("clean");
    let findings = run_all(&repo.root).expect("lint runs");
    assert!(findings.is_empty(), "unexpected findings:\n{}", rendered(&findings));
}

// ---------------------------------------------------------------- pass 1

#[test]
fn registry_pass_catches_readme_param_drift() {
    let repo = clean_repo("readme-drift");
    // Drop `beta` from the README param table: README drifts from the code.
    repo.write("rust/README.md", &README_MD.replace("| `beta` | another knob |\n", ""));
    let findings = run_all(&repo.root).expect("lint runs");
    assert_eq!(findings.len(), 1, "want 1 finding:\n{}", rendered(&findings));
    assert_eq!(findings[0].rule, "readme-table");
    assert!(findings[0].message.contains("beta"), "{}", findings[0].message);
}

#[test]
fn registry_pass_catches_builder_match_drift() {
    let repo = clean_repo("builder-drift");
    // A policy registered in SELECTION_NAMES but missing from the builder.
    repo.write(
        "rust/src/model/policy.rs",
        &POLICY_RS.replace("            \"two\" => {}\n", ""),
    );
    let findings = run_all(&repo.root).expect("lint runs");
    assert_eq!(findings.len(), 1, "want 1 finding:\n{}", rendered(&findings));
    assert_eq!(findings[0].rule, "policy-drift");
    assert!(findings[0].message.contains("two"), "{}", findings[0].message);
}

#[test]
fn registry_pass_catches_unvalidated_param() {
    let repo = clean_repo("validate-drift");
    // `beta` covered neither by a range check nor by TYPE_ENFORCED_PARAMS.
    repo.write(
        "rust/src/config/validate.rs",
        &VALIDATE_RS.replace("&[\"beta\"]", "&[]"),
    );
    let findings = run_all(&repo.root).expect("lint runs");
    assert_eq!(findings.len(), 1, "want 1 finding:\n{}", rendered(&findings));
    assert_eq!(findings[0].rule, "param-drift");
    assert!(findings[0].message.contains("beta"), "{}", findings[0].message);
}

// ---------------------------------------------------------------- pass 2

#[test]
fn determinism_pass_catches_hash_container_and_accepts_annotation() {
    let bad = "use std::collections::HashMap;\npub struct S {\n    m: HashMap<u32, u32>,\n}\n";
    let findings = determinism::scan_file("rust/src/model/bad.rs", bad);
    assert_eq!(findings.len(), 2, "{}", rendered(&findings));
    assert!(findings.iter().all(|f| f.rule == "hash-container"));

    let annotated = "// lint:allow(hash-container) keyed lookups only, audited\n\
                     use std::collections::HashMap;\n\
                     pub struct S {\n    \
                     // lint:allow(hash-container) keyed lookups only, audited\n    \
                     m: HashMap<u32, u32>,\n}\n";
    let findings = determinism::scan_file("rust/src/model/bad.rs", annotated);
    assert!(findings.is_empty(), "{}", rendered(&findings));

    // An annotation without a reason is itself a finding.
    let bare = "use std::collections::HashMap; // lint:allow(hash-container)\n";
    let findings = determinism::scan_file("rust/src/model/bad.rs", bare);
    assert_eq!(findings.len(), 1, "{}", rendered(&findings));
    assert_eq!(findings[0].rule, "allow-reason");
}

#[test]
fn determinism_pass_ignores_tests_comments_and_strings() {
    let src = "// HashMap in a comment is fine\n\
               pub const DOC: &str = \"HashMap in a string is fine\";\n\
               #[cfg(test)]\n\
               mod tests {\n    \
               use std::collections::HashMap;\n    \
               fn t() { let _m: HashMap<u32, u32> = HashMap::new(); }\n}\n";
    let findings = determinism::scan_file("rust/src/model/ok.rs", src);
    assert!(findings.is_empty(), "{}", rendered(&findings));
}

#[test]
fn determinism_pass_catches_wall_clock_and_float_accum() {
    let src = "use std::time::Instant;\n";
    let findings = determinism::scan_file("rust/src/sim/clock.rs", src);
    assert_eq!(findings.len(), 1, "{}", rendered(&findings));
    assert_eq!(findings[0].rule, "wall-clock");

    let src = "fn reduce(total: &Mutex<f64>, dt: f64) {\n    \
               let mut t = total.lock().unwrap();\n    \
               *t += dt;\n}\n";
    let findings = determinism::scan_file("rust/src/sweep/reduce.rs", src);
    assert_eq!(findings.len(), 1, "{}", rendered(&findings));
    assert_eq!(findings[0].rule, "float-accum");

    // Integer counters are exact in any accumulation order.
    let src = "fn count(n: &Mutex<u64>) {\n    \
               let mut t = n.lock().unwrap();\n    \
               *t += 1;\n}\n";
    let findings = determinism::scan_file("rust/src/sweep/reduce.rs", src);
    assert!(findings.is_empty(), "{}", rendered(&findings));
}

// ---------------------------------------------------------------- pass 3

#[test]
fn draws_pass_catches_new_site_and_stale_entry() {
    let repo = clean_repo("draws");
    // Seed a second draw: the committed count (1) no longer matches.
    repo.write(
        "rust/src/model/x.rs",
        "pub fn roll(rng: &mut Rng) -> bool {\n    rng.bernoulli(0.5) && rng.bernoulli(0.1)\n}\n",
    );
    let findings = run_all(&repo.root).expect("lint runs");
    assert_eq!(findings.len(), 1, "{}", rendered(&findings));
    assert_eq!(findings[0].rule, "draw-site");
    assert!(findings[0].message.contains("allowlist says 1"), "{}", findings[0].message);

    // A brand-new method not in the allowlist at all.
    let repo = clean_repo("draws-new");
    repo.write(
        "rust/src/model/y.rs",
        "pub fn pick(rng: &mut Rng) -> u64 {\n    rng.next_below(7)\n}\n",
    );
    let findings = run_all(&repo.root).expect("lint runs");
    assert_eq!(findings.len(), 1, "{}", rendered(&findings));
    assert!(findings[0].message.contains("new draw site"), "{}", findings[0].message);

    // A stale allowlist entry (site removed, entry kept) also fails.
    let repo = clean_repo("draws-stale");
    repo.write("rust/src/model/x.rs", "pub fn roll() -> bool {\n    true\n}\n");
    let findings = run_all(&repo.root).expect("lint runs");
    assert_eq!(findings.len(), 1, "{}", rendered(&findings));
    assert!(findings[0].message.contains("stale entry"), "{}", findings[0].message);
}

#[test]
fn draws_pass_skips_test_code() {
    let counts = draws::count_draws(
        "pub fn live(rng: &mut Rng) -> f64 {\n    rng.next_f64()\n}\n\
         #[cfg(test)]\nmod tests {\n    \
         fn t(rng: &mut Rng) { rng.next_f64(); rng.bernoulli(0.5); }\n}\n",
    );
    assert_eq!(counts.get("next_f64"), Some(&1));
    assert_eq!(counts.get("bernoulli"), None);
}

// ---------------------------------------------------------------- pass 4

#[test]
fn configs_pass_catches_unknown_names() {
    let repo = clean_repo("configs");
    repo.write(
        "rust/configs/bad.yaml",
        "title: bad\nparams:\n  gamma: 1.0\npolicies: { selection: three }\nbudgett: 2\n",
    );
    let findings = run_all(&repo.root).expect("lint runs");
    let rules: Vec<&str> = findings.iter().map(|f| f.rule.as_str()).collect();
    assert_eq!(
        rules,
        vec!["unknown-key", "unknown-param", "unknown-policy"],
        "{}",
        rendered(&findings)
    );
    assert!(findings.iter().all(|f| f.file.ends_with("bad.yaml")));
}

#[test]
fn configs_pass_checks_sweep_axes_and_scenario_kind() {
    let repo = clean_repo("configs-sweep");
    repo.write(
        "rust/configs/sweep.yaml",
        "scenario: sweeep\nsweep:\n  kind: one_way\n  x:\n    name: policies.selection\n    values: [one, three]\n",
    );
    let findings = run_all(&repo.root).expect("lint runs");
    let rules: Vec<&str> = findings.iter().map(|f| f.rule.as_str()).collect();
    assert_eq!(
        rules,
        vec!["scenario-kind", "unknown-policy"],
        "{}",
        rendered(&findings)
    );
}

#[test]
fn configs_check_doc_accepts_the_full_feature_surface() {
    // One doc exercising every structural section the linter knows.
    let (regs, findings) = registry::check(&clean_repo("regs").root).expect("registry");
    assert!(findings.is_empty(), "{}", rendered(&findings));
    let doc = "title: all\nscenario: single\nseed: 7\nreplications: 4\n\
               params:\n  alpha: 1.0\n  beta: 2.0\n\
               policies: { selection: two }\n\
               topology:\n  levels:\n    - {name: rack, size: 8, outage_rate: 0.1}\n\
               workload:\n  poisson: { rate: 0.5 }\n  classes:\n    - {weight: 1, job_size: 4}\n\
               children:\n  - label: a\n    params:\n      alpha: 3.0\n";
    let findings = configs::check_doc("rust/configs/all.yaml", doc, &regs);
    assert!(findings.is_empty(), "{}", rendered(&findings));
}

// ------------------------------------------------------------ integration

#[test]
fn lint_runs_clean_on_this_repo() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..");
    let findings = run_all(&root).expect("lint runs on the real repo");
    assert!(
        findings.is_empty(),
        "the repo must lint clean; findings:\n{}",
        rendered(&findings)
    );
}
